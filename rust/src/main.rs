//! `asyncmel` — CLI launcher for the asynchronous-MEL orchestrator.
//!
//! Subcommands map 1:1 to the paper's experiments:
//!
//! ```text
//! asyncmel info                          # environment + artifact status
//! asyncmel solve --k 20 --t 7.5          # one allocation, all schemes side by side
//! asyncmel fig2 [--seeds 5] [--csv f]    # staleness sweep (paper Fig. 2)
//! asyncmel fig3 [--cycles 12] [--ks 10,15,20] [--samples 60000]
//! asyncmel train --k 10 --scheme relaxed --cycles 10
//! asyncmel train --engine event --async --churn-join 0.5 --churn-life 120
//! asyncmel fleet --ks 10,100,1000,5000   # event-engine scaling sweep
//! asyncmel ablation [--seeds 5]          # bounds sensitivity (ABL-1)
//! ```
//!
//! Global flag: `--config <json>` loads a [`ScenarioConfig`] override
//! file (sparse — absent fields keep the paper defaults).

use std::path::PathBuf;

use anyhow::{bail, Result};

use asyncmel::aggregation::{AggregationRule, AsyncAggregator, StalenessDecay};
use asyncmel::allocation::{make_allocator, AllocatorKind};
use asyncmel::cli::Args;
use asyncmel::config::{ChurnConfig, EngineKind, Scenario, ScenarioConfig, TraceConfig};
use asyncmel::coordinator::{
    EngineOptions, EnginePolicy, EventEngine, ExecMode, Orchestrator, TrainOptions,
};
use asyncmel::data::{synth, SynthConfig, SynthDataset};
use asyncmel::experiments::{ablation, energy_sweep, fig2, fig3, fleet_scale, multi_model};
use asyncmel::metrics::{fmt_f, fmt_opt_u, Table};
use asyncmel::multimodel::{
    AdaptiveBufferConfig, ModelTaskSpec, MultiModelConfig, MultiModelOptions, SchedulerKind,
};
use asyncmel::runtime::{default_artifacts_dir, Runtime};
use asyncmel::serve::ServeOptions;

const USAGE: &str =
    "usage: asyncmel <info|solve|fig2|fig3|train|fleet|multi|ablation|energy-sweep|serve|trace-gen> [flags]
  info                               environment + artifact status
  solve    --k N --t SECS            compare all allocation schemes
  fig2     --seeds N --csv PATH      staleness vs K sweep (paper Fig. 2)
  fig3     --cycles N --ks 10,15,20 --samples D --csv PATH
  train    --k N --t SECS --scheme S --aggregation A --cycles N --lr F --samples D
           --threads N               worker threads for real-numerics learner steps
                                     (0 = all cores; any value is bit-identical)
           --epsilon-window S        event engine: coalesce async arrivals within S
                                     virtual seconds and fan their train steps out
                                     together (0 = simultaneous-only, the default;
                                     byte-identical to per-event dispatch)
           --shards K                event engine: hierarchical coordinator shards
                                     (learner events route to shard slot%K; any K
                                     is bit-identical to the flat K=1 coordinator)
           --engine lockstep|event   coordinator engine (default: config)
           --async [--alpha F]       event engine: staleness-weighted async aggregation
           --churn-join R --churn-life S   event engine: joins/s + mean lifetime (s)
           --models M --buffer B
           --scheduler static|round-robin|staleness-greedy|cost-model
                                     event engine: concurrent multi-model training
                                     (cost-model routes by predicted completion time)
           --hetero                  mixed small/large per-model tasks (odd models:
                                     quarter model dims + compute, half the dataset)
           --adaptive-buffer BMAX [--buffer-target S --buffer-alpha A]
                                     FedAST-style adaptive B in [1, BMAX], retuned
                                     from the observed staleness EWMA
           --fading-rho RHO          event engine: per-cycle Gauss-Markov link fading
           --energy-budget J         event engine: per-learner per-cycle energy cap
                                     E_k^max in joules ('inf' = unconstrained); the
                                     allocator clips infeasible (tau, d) to the
                                     energy-feasible frontier before repair
           --comm-loss P             event engine: per-message loss probability
                                     (both link directions; deliveries time out and
                                     retry with capped exponential backoff)
           --comm-dup P --comm-corrupt P
                                     duplicate / corrupt probabilities (dupes dedup
                                     at the aggregator, corruption is caught by
                                     checksum and dropped)
  fleet    --ks 10,100,1000,5000 --cycles N --scheme S
           --churn-join R --churn-life S --shards K --csv PATH
           --energy-budget J         per-learner energy cap for the sweep
           --comm-loss P --comm-dup P --comm-corrupt P
                                     comm-fault chaos for the sweep
                                     event-engine scaling sweep (phantom numerics)
           --real [--threads N] [--epsilon-window S] [--energy-budget J]
                                     real-numerics sweep instead (native MLP through
                                     the sharded executor; default ks 100,500,1000),
                                     plus an async serial/sharded/coalescing sweep
  multi    --ks 100,1000 --ms 1,2,4,8 --buffer B --scheduler S --budget N
           --cycles N --scheme S --churn-join R --churn-life S --csv PATH
           --hetero --adaptive-buffer BMAX [--buffer-target S --buffer-alpha A]
                                     multi-model concurrency sweep (phantom numerics)
  ablation --seeds N --csv PATH      batch-bounds sensitivity (ABL-1)
  energy-sweep --budgets inf,40,25,18,12 --k N --cycles N --scheme S --csv PATH
                                     staleness/churn vs energy budget E_k^max;
                                     the 'inf' point is digest-checked against the
                                     unconstrained allocator (differential oracle)
  serve    --spool DIR               daemon: watch DIR for submission JSON files
           --once                    drain the queue, then exit (no polling)
           --poll-ms MS              idle poll interval (default 200)
           --checkpoint-every N      suspend + checkpoint each job every N cycles
                                     (0 = run start-to-finish; resume after a kill
                                     is bit-identical to an uninterrupted run)
           --stop-after N            exit after N checkpointed segments (CI's
                                     deterministic stand-in for kill -9)
           --format json|json-compact  result encoding
           --stdin                   one-line JSON submissions on stdin instead
  trace-gen <diurnal|flash|outage> [--seed N --regions R --out PATH]
           diurnal: --horizon S --period S --steps N --base K --peak K
           flash:   --start S --steps N --joins K --hold S
           outage:  --horizon S --outages N --fraction F --recover S --alive K
                                     seeded churn-trace generators (JSON to stdout
                                     or --out; load via ScenarioConfig.trace)
global: --config PATH (sparse scenario JSON override)";

/// Paper model stack for artifact-free runs.
const PAPER_DIMS: [usize; 5] = [784, 300, 124, 60, 10];

/// Load the compiled artifacts if present, otherwise fall back to the
/// hermetic native executor on the paper's model stack.
fn load_runtime() -> Runtime {
    match Runtime::load(default_artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("note: artifacts not loaded ({e:#}); using the native executor");
            Runtime::native(&PAPER_DIMS, 128, 512)
        }
    }
}

/// Churn overrides from the CLI on top of the scenario config.
fn churn_from_args(base: ChurnConfig, args: &Args) -> Result<ChurnConfig> {
    let mut churn = base;
    churn.join_rate_per_s = args.get_or("churn-join", churn.join_rate_per_s)?;
    churn.mean_lifetime_s = args.get_or("churn-life", churn.mean_lifetime_s)?;
    churn.max_learners = args.get_or("churn-max", churn.max_learners)?;
    churn.min_learners = args.get_or("churn-min", churn.min_learners)?;
    if churn.join_rate_per_s < 0.0 {
        bail!("--churn-join must be >= 0 (joins per second)");
    }
    if churn.mean_lifetime_s < 0.0 {
        bail!("--churn-life must be >= 0 (seconds)");
    }
    Ok(churn)
}

fn base_config(args: &Args) -> Result<ScenarioConfig> {
    Ok(match args.get("config") {
        Some(path) => ScenarioConfig::load(path)?,
        None => ScenarioConfig::paper_default(),
    })
}

/// `--adaptive-buffer BMAX [--buffer-target S --buffer-alpha A]` →
/// adaptive buffer config (None when the flag is absent).
fn adaptive_from_args(args: &Args) -> Result<Option<AdaptiveBufferConfig>> {
    if args.get("adaptive-buffer").is_none() {
        return Ok(None);
    }
    let a = AdaptiveBufferConfig {
        b_max: args.require("adaptive-buffer")?,
        target_staleness: args.get_or("buffer-target", 2.0)?,
        ewma_alpha: args.get_or("buffer-alpha", 0.25)?,
    };
    if let Err(e) = a.validate() {
        bail!("--adaptive-buffer/--buffer-target/--buffer-alpha: {e}");
    }
    Ok(Some(a))
}

fn cmd_info(base: &ScenarioConfig) {
    println!("asyncmel {} — async federated MEL", env!("CARGO_PKG_VERSION"));
    println!(
        "scenario: K={} T={}s d={} bounds=({},{})·d/K",
        base.num_learners, base.t_cycle_s, base.total_samples, base.d_lo_frac, base.d_hi_frac
    );
    let dir = default_artifacts_dir();
    match Runtime::load(&dir) {
        Ok(rt) => println!(
            "artifacts: OK ({}), platform={}, model dims {:?}",
            dir.display(),
            rt.platform(),
            rt.manifest.layer_dims
        ),
        Err(e) => println!("artifacts: NOT LOADED ({e:#}) — run `make artifacts`"),
    }
}

fn cmd_solve(base: ScenarioConfig, args: &Args) -> Result<()> {
    let k: usize = args.get_or("k", 10)?;
    let t: f64 = args.get_or("t", 15.0)?;
    let seed_offset: u64 = args.get_or("seed-offset", 0)?;
    let scenario = base
        .with_learners(k)
        .with_cycle(t)
        .with_seed(ScenarioConfig::paper_default().seed + seed_offset)
        .build();
    let mut table = Table::new(&["scheme", "max_stale", "avg_stale", "util", "solve_ms", "tau"]);
    for kind in AllocatorKind::all() {
        let alloc = make_allocator(kind);
        let t0 = std::time::Instant::now();
        match alloc.allocate(
            &scenario.costs,
            scenario.t_cycle(),
            scenario.total_samples(),
            &scenario.bounds,
        ) {
            Ok(a) => {
                table.row(&[
                    kind.name().into(),
                    a.max_staleness().to_string(),
                    fmt_f(a.avg_staleness(), 2),
                    fmt_f(a.mean_utilization(&scenario.costs, t), 3),
                    fmt_f(t0.elapsed().as_secs_f64() * 1e3, 3),
                    format!("{:?}", a.tau),
                ]);
            }
            Err(e) => {
                table.row(&[
                    kind.name().into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("infeasible: {e}"),
                ]);
            }
        }
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_fig2(base: ScenarioConfig, args: &Args) -> Result<()> {
    let seeds: usize = args.get_or("seeds", 5)?;
    let params = fig2::Fig2Params { base, seeds, ..Default::default() };
    let rows = fig2::run(&params)?;
    let table = fig2::table(&rows);
    println!("{}", table.render());
    if let Some((om, em, oa, ea)) = fig2::headline(&rows) {
        println!(
            "§V-B headline (K=20, T=7.5s): opt max {om:.2} vs ETA {em:.2} | opt avg {oa:.2} vs ETA {ea:.2}"
        );
    }
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("csv -> {path}");
    }
    Ok(())
}

fn cmd_fig3(base: ScenarioConfig, args: &Args) -> Result<()> {
    let cycles: usize = args.get_or("cycles", 12)?;
    let ks: Vec<usize> = args.get_list_or("ks", vec![10, 15, 20])?;
    let samples: u64 = args.get_or("samples", 60_000)?;
    let schemes: Vec<AllocatorKind> = args.get_list_or(
        "schemes",
        vec![AllocatorKind::Relaxed, AllocatorKind::Sync, AllocatorKind::Eta],
    )?;
    let runtime = load_runtime();
    let base = base.with_total_samples(samples);
    let params = fig3::Fig3Params {
        data: SynthConfig {
            train: samples as usize,
            test: (samples as usize / 6).max(512),
            ..SynthConfig::default()
        },
        ks,
        cycles,
        base,
        schemes,
        ..Default::default()
    };
    let curves = fig3::run(&runtime, &params)?;
    println!("{}", fig3::table(&curves).render());
    println!("{}", fig3::summary_table(&curves, &[0.95, 0.97]).render());
    if let Some(path) = args.get("csv") {
        fig3::table(&curves).save_csv(path)?;
        println!("csv -> {path}");
    }
    Ok(())
}

/// `--epsilon-window S` → scenario override, validated like the config
/// parser (finite, >= 0).
fn epsilon_from_args(base: &mut ScenarioConfig, args: &Args) -> Result<()> {
    let eps: f64 = args.get_or("epsilon-window", base.epsilon_window)?;
    if let Err(e) = asyncmel::config::validate_epsilon_window(eps) {
        bail!("--epsilon-window: {e}");
    }
    base.epsilon_window = eps;
    Ok(())
}

/// `--energy-budget J` → per-learner per-cycle allocation budget
/// `E_k^max` on the scenario's energy config (`inf` = unconstrained,
/// the default). Returns whether the flag was given: the budget path
/// lives in the event engine's allocator wrapper, so callers reject
/// the flag on the lock-step orchestrator.
fn energy_from_args(base: &mut ScenarioConfig, args: &Args) -> Result<bool> {
    if args.get("energy-budget").is_none() {
        return Ok(false);
    }
    let budget: f64 = args.require("energy-budget")?;
    base.energy.budget_j = budget;
    if let Err(e) = base.energy.validate() {
        bail!("--energy-budget: {e}");
    }
    Ok(true)
}

/// `--comm-loss P --comm-dup P --comm-corrupt P` → comm-fault chaos
/// overrides on the scenario's `comm` section (`--comm-loss` sets both
/// link directions; use a config file for asymmetric links). Returns
/// whether any flag was given: the fault layer lives in the event
/// engine, so callers reject the flags on the lock-step orchestrator.
fn comm_from_args(base: &mut ScenarioConfig, args: &Args) -> Result<bool> {
    let given = ["comm-loss", "comm-dup", "comm-corrupt"]
        .iter()
        .any(|k| args.get(k).is_some());
    if !given {
        return Ok(false);
    }
    if args.get("comm-loss").is_some() {
        let p: f64 = args.require("comm-loss")?;
        base.comm.downlink_loss_prob = p;
        base.comm.uplink_loss_prob = p;
    }
    base.comm.duplicate_prob = args.get_or("comm-dup", base.comm.duplicate_prob)?;
    base.comm.corrupt_prob = args.get_or("comm-corrupt", base.comm.corrupt_prob)?;
    if let Err(e) = base.comm.validate() {
        bail!("--comm-loss/--comm-dup/--comm-corrupt: {e}");
    }
    Ok(true)
}

/// `--shards K` → scenario override: hierarchical coordinator shard
/// count (rejects 0, same as the JSON intake path).
fn shards_from_args(base: &mut ScenarioConfig, args: &Args) -> Result<()> {
    let shards: usize = args.get_or("shards", base.num_shards)?;
    if shards == 0 {
        bail!("--shards must be >= 1 (coordinator shard count)");
    }
    base.num_shards = shards;
    Ok(())
}

fn cmd_train(mut base: ScenarioConfig, args: &Args) -> Result<()> {
    base.num_threads = args.get_or("threads", base.num_threads)?;
    epsilon_from_args(&mut base, args)?;
    shards_from_args(&mut base, args)?;
    let k: usize = args.get_or("k", 10)?;
    let t: f64 = args.get_or("t", 15.0)?;
    let scheme: AllocatorKind = args.get_or("scheme", AllocatorKind::Relaxed)?;
    let aggregation: AggregationRule = args.get_or("aggregation", AggregationRule::FedAvg)?;
    let cycles: usize = args.get_or("cycles", 10)?;
    let lr: f32 = args.get_or("lr", 0.01)?;
    let samples: u64 = args.get_or("samples", 60_000)?;
    let mut engine: EngineKind = args.get_or("engine", base.engine)?;
    let multi_flags_given = ["models", "buffer", "scheduler", "adaptive-buffer"]
        .iter()
        .any(|k| args.get(k).is_some())
        || args.has("hetero");
    let multi_requested = multi_flags_given || base.multimodel.is_multi();
    if (args.has("async") || multi_requested) && engine == EngineKind::Lockstep {
        if args.get("engine").is_some() && !multi_flags_given && !args.has("async") {
            // an explicit --engine lockstep must not lose silently to a
            // config-file multimodel section
            bail!(
                "the config requests multi-model training but --engine lockstep was given; \
                 drop --engine lockstep or set multimodel.num_models = 1"
            );
        }
        // these knobs only exist on the event engine; asking for them
        // (on the CLI or via a multimodel config section) implies it
        eprintln!(
            "note: --async/--models/--buffer/--scheduler (or a multimodel config) imply --engine event"
        );
        engine = EngineKind::Event;
    }
    let churn = churn_from_args(base.churn, args)?;
    let churn_flags_given = ["churn-join", "churn-life", "churn-max", "churn-min"]
        .iter()
        .any(|k| args.get(k).is_some());
    if churn_flags_given && engine == EngineKind::Lockstep {
        bail!("churn flags require --engine event (the lock-step orchestrator has no churn model)");
    }
    let energy_flag_given = energy_from_args(&mut base, args)?;
    if (energy_flag_given || base.energy.is_enabled()) && engine == EngineKind::Lockstep {
        bail!(
            "--energy-budget (and energy config sections) require --engine event \
             (the budgeted allocator and battery churn live in the event engine)"
        );
    }
    if args.get("fading-rho").is_some() {
        let rho: f64 = args.require("fading-rho")?;
        if !(0.0..=1.0).contains(&rho) {
            bail!("--fading-rho must be in [0, 1], got {rho}");
        }
        if engine == EngineKind::Lockstep {
            bail!("--fading-rho requires --engine event (per-cycle link evolution)");
        }
        base.fading_rho = Some(rho);
    }
    let comm_flags_given = comm_from_args(&mut base, args)?;
    if (comm_flags_given || base.comm.is_enabled()) && engine == EngineKind::Lockstep {
        bail!(
            "--comm-loss/--comm-dup/--comm-corrupt (and comm config sections) require \
             --engine event (the fault layer lives in the event engine)"
        );
    }
    let models: usize = args.get_or("models", base.multimodel.num_models)?;
    let buffer: usize = args.get_or("buffer", base.multimodel.buffer_size)?;
    let scheduler: SchedulerKind = args.get_or("scheduler", base.multimodel.scheduler)?;
    if models == 0 || buffer == 0 {
        bail!("--models and --buffer must be >= 1");
    }
    // config weights carry over only when they still match the model count
    let weights = if base.multimodel.weights.len() == models {
        base.multimodel.weights.clone()
    } else {
        Vec::new()
    };
    let mut mm_cfg = MultiModelConfig::new(models, buffer, scheduler).with_weights(weights);
    mm_cfg.adaptive_buffer = adaptive_from_args(args)?.or(base.multimodel.adaptive_buffer);
    // --hetero generates the mixed small/large spec set; otherwise a
    // config-file spec list carries over while it matches the count
    mm_cfg.specs = if args.has("hetero") {
        ModelTaskSpec::small_large_mix(models, samples, &base.task)
    } else if base.multimodel.specs.len() == models {
        base.multimodel.specs.clone()
    } else {
        Vec::new()
    };

    let runtime = load_runtime();
    let scenario = base
        .with_learners(k)
        .with_cycle(t)
        .with_total_samples(samples)
        .with_churn(churn)
        .build();
    let ds = synth::generate(&SynthConfig {
        train: samples as usize,
        test: (samples as usize / 6).max(512),
        ..SynthConfig::default()
    });
    let train_opts = TrainOptions {
        cycles,
        lr,
        eval_every: 1,
        reallocate_each_cycle: false,
    };
    if engine == EngineKind::Event && (mm_cfg.is_multi() || multi_flags_given) {
        let alpha: f64 = args.get_or("alpha", 0.6)?;
        if !(alpha > 0.0 && alpha <= 1.0) {
            bail!("--alpha must be in (0, 1], got {alpha}");
        }
        return train_multi(scenario, scheme, aggregation, &runtime, ds, train_opts, mm_cfg, alpha);
    }
    let records = match engine {
        EngineKind::Lockstep => {
            let mut orch =
                Orchestrator::new(scenario, scheme, aggregation, &runtime, ds.train, ds.test)?;
            orch.run(&train_opts)?
        }
        EngineKind::Event => {
            let policy = if args.has("async") {
                let alpha: f64 = args.get_or("alpha", 0.6)?;
                if !(alpha > 0.0 && alpha <= 1.0) {
                    bail!("--alpha must be in (0, 1], got {alpha}");
                }
                EnginePolicy::Async(AsyncAggregator::new(
                    alpha,
                    StalenessDecay::Polynomial { a: 0.5 },
                ))
            } else {
                EnginePolicy::Barrier
            };
            let mut eng = EventEngine::new(
                scenario,
                scheme,
                aggregation,
                ExecMode::Real { runtime: &runtime, train: ds.train, test: ds.test },
            )?;
            let recs = eng.run(&EngineOptions { train: train_opts, policy })?;
            eprintln!(
                "engine stats: {} events, {} arrivals, {} joins, {} leaves, {} re-solves, {} alive",
                eng.stats.events,
                eng.stats.arrivals,
                eng.stats.joins,
                eng.stats.leaves,
                eng.stats.resolves,
                eng.stats.final_alive
            );
            recs
        }
    };
    let mut table = Table::new(&["cycle", "vtime_s", "train_loss", "accuracy", "max_stale", "util"]);
    for r in &records {
        table.row(&[
            (r.cycle + 1).to_string(),
            fmt_f(r.vtime_s, 1),
            fmt_f(r.train_loss as f64, 4),
            fmt_f(r.accuracy, 4),
            r.max_staleness.to_string(),
            fmt_f(r.utilization, 3),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Multi-model training through the event engine (real numerics): one
/// table row per (model, cycle) plus a per-model summary.
#[allow(clippy::too_many_arguments)]
fn train_multi(
    scenario: Scenario,
    scheme: AllocatorKind,
    aggregation: AggregationRule,
    runtime: &Runtime,
    ds: SynthDataset,
    train_opts: TrainOptions,
    mm_cfg: MultiModelConfig,
    alpha: f64,
) -> Result<()> {
    let mut eng = EventEngine::new(
        scenario,
        scheme,
        aggregation,
        ExecMode::Real { runtime, train: ds.train, test: ds.test },
    )?;
    let opts = MultiModelOptions {
        train: train_opts,
        aggregator: AsyncAggregator::new(alpha, StalenessDecay::Polynomial { a: 0.5 }),
        multi: mm_cfg,
        ..Default::default()
    };
    let report = eng.run_multi(&opts)?;
    eprintln!(
        "engine stats: {} events, {} arrivals, {} joins, {} leaves, {} re-solves, {} alive",
        eng.stats.events,
        eng.stats.arrivals,
        eng.stats.joins,
        eng.stats.leaves,
        eng.stats.resolves,
        eng.stats.final_alive
    );
    let mut table = Table::new(&[
        "model", "cycle", "vtime_s", "train_loss", "accuracy", "max_stale", "util",
    ]);
    for (m, records) in report.records.iter().enumerate() {
        for r in records {
            table.row(&[
                m.to_string(),
                (r.cycle + 1).to_string(),
                fmt_f(r.vtime_s, 1),
                fmt_f(r.train_loss as f64, 4),
                fmt_f(r.accuracy, 4),
                r.max_staleness.to_string(),
                fmt_f(r.utilization, 3),
            ]);
        }
    }
    println!("{}", table.render());
    let mut summary = Table::new(&["model", "weight", "arrivals", "applied", "slots", "sum_d"]);
    for s in &report.stats {
        summary.row(&[
            s.model.to_string(),
            fmt_f(s.weight, 3),
            s.arrivals.to_string(),
            s.applied.to_string(),
            s.assigned_slots.to_string(),
            fmt_opt_u(s.final_sum_d),
        ]);
    }
    println!("{}", summary.render());
    Ok(())
}

fn cmd_multi(base: ScenarioConfig, args: &Args) -> Result<()> {
    let ks: Vec<usize> = args.get_list_or("ks", vec![100, 1000])?;
    let ms: Vec<usize> = args.get_list_or("ms", vec![1, 2, 4, 8])?;
    let buffer: usize = args.get_or("buffer", 4)?;
    let scheduler: SchedulerKind = args.get_or("scheduler", SchedulerKind::StalenessGreedy)?;
    let cycles: usize = args.get_or("cycles", 6)?;
    let scheme: AllocatorKind = args.get_or("scheme", AllocatorKind::Eta)?;
    let budget: u64 = args.get_or("budget", 64)?;
    let hetero = args.has("hetero");
    let adaptive = adaptive_from_args(args)?.or(base.multimodel.adaptive_buffer);
    let churn_base = if base.churn.is_enabled() { base.churn } else { ChurnConfig::new(1.0, 120.0) };
    let churn = churn_from_args(churn_base, args)?;
    let params = multi_model::MultiModelParams {
        base,
        ks,
        ms,
        buffer,
        scheduler,
        cycles,
        scheme,
        churn,
        aggregator: AsyncAggregator::default(),
        round_budget: if budget == 0 { None } else { Some(budget) },
        hetero,
        adaptive,
    };
    let rows = multi_model::run(&params)?;
    let table = multi_model::table(&rows);
    println!("{}", table.render());
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("csv -> {path}");
    }
    Ok(())
}

fn cmd_fleet(mut base: ScenarioConfig, args: &Args) -> Result<()> {
    base.num_threads = args.get_or("threads", base.num_threads)?;
    epsilon_from_args(&mut base, args)?;
    shards_from_args(&mut base, args)?;
    energy_from_args(&mut base, args)?;
    comm_from_args(&mut base, args)?;
    if args.has("real") {
        return cmd_fleet_real(base, args);
    }
    let ks: Vec<usize> = args.get_list_or("ks", vec![10, 100, 1000, 5000])?;
    let cycles: usize = args.get_or("cycles", 8)?;
    let scheme: AllocatorKind = args.get_or("scheme", AllocatorKind::Eta)?;
    // honor churn from --config when present; otherwise default to a
    // visibly churny fleet (the point of the sweep)
    let churn_base = if base.churn.is_enabled() { base.churn } else { ChurnConfig::new(1.0, 120.0) };
    let churn = churn_from_args(churn_base, args)?;
    let num_shards = base.num_shards;
    let params = fleet_scale::FleetScaleParams { base, ks, cycles, scheme, churn, num_shards };
    let rows = fleet_scale::run(&params)?;
    let table = fleet_scale::table(&rows);
    println!("{}", table.render());
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("csv -> {path}");
    }
    Ok(())
}

/// `fleet --real`: the real-numerics sweep through the sharded executor
/// (ROADMAP "ExecMode::Real past a few hundred learners").
fn cmd_fleet_real(base: ScenarioConfig, args: &Args) -> Result<()> {
    if ["churn-join", "churn-life", "churn-max", "churn-min"]
        .iter()
        .any(|k| args.get(k).is_some())
    {
        bail!("fleet --real has no churn model yet; drop the --churn-* flags");
    }
    let defaults = fleet_scale::RealFleetParams::default();
    let ks: Vec<usize> = args.get_list_or("ks", defaults.ks.clone())?;
    let cycles: usize = args.get_or("cycles", defaults.cycles)?;
    let scheme: AllocatorKind = args.get_or("scheme", defaults.scheme)?;
    let threads = if args.get("threads").is_some() {
        vec![base.num_threads]
    } else {
        defaults.threads.clone()
    };
    let params = fleet_scale::RealFleetParams {
        base: fleet_scale::real_base(&base),
        ks,
        cycles,
        scheme,
        threads,
        ..defaults
    };
    let rows = fleet_scale::run_real(&params)?;
    let table = fleet_scale::real_table(&rows);
    println!("{}", table.render());
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("csv -> {path}");
    }
    // async-real comparison: per-arrival aggregation at serial vs
    // sharded (per-event) vs sharded + ε-window coalescing. An explicit
    // --epsilon-window always wins (including 0 = simultaneous-only);
    // otherwise ε defaults to 1 s of virtual time for the sweep — at
    // ε = 0 the window only merges simultaneous arrivals, which a
    // free-running stream essentially never produces.
    let eps = if args.get("epsilon-window").is_some() || params.base.epsilon_window > 0.0 {
        params.base.epsilon_window
    } else {
        1.0
    };
    println!("async-real sweep (steps/s; coalesce ε = {eps}s):");
    let async_rows = fleet_scale::run_async_real(&params, eps)?;
    println!("{}", fleet_scale::async_real_table(&async_rows).render());
    Ok(())
}

/// `asyncmel energy-sweep` — staleness/utilization/churn vs the
/// per-learner energy budget, with the unconstrained allocator as a
/// byte-identity oracle at `∞` (see [`energy_sweep`]).
fn cmd_energy_sweep(base: ScenarioConfig, args: &Args) -> Result<()> {
    let defaults = energy_sweep::EnergySweepParams::default();
    let k: usize = args.get_or("k", defaults.k)?;
    let cycles: usize = args.get_or("cycles", defaults.cycles)?;
    let scheme: AllocatorKind = args.get_or("scheme", defaults.scheme)?;
    let budgets: Vec<f64> = args.get_list_or("budgets", defaults.budgets.clone())?;
    if budgets.is_empty() {
        bail!("--budgets needs at least one value (joules; 'inf' = unconstrained)");
    }
    let churn_base = if base.churn.is_enabled() { base.churn } else { defaults.churn };
    let churn = churn_from_args(churn_base, args)?;
    let params = energy_sweep::EnergySweepParams { base, k, cycles, scheme, churn, budgets };
    let rows = energy_sweep::run(&params)?;
    let table = energy_sweep::table(&rows);
    println!("{}", table.render());
    if rows.iter().any(|r| r.oracle_match == Some(false)) {
        bail!("budget-∞ run diverged from the unconstrained oracle (determinism bug)");
    }
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("csv -> {path}");
    }
    Ok(())
}

fn cmd_ablation(base: ScenarioConfig, args: &Args) -> Result<()> {
    let seeds: usize = args.get_or("seeds", 5)?;
    let params = ablation::AblationParams {
        base: base.with_learners(20).with_cycle(7.5),
        seeds,
        ..Default::default()
    };
    let rows = ablation::run(&params)?;
    let table = ablation::table(&rows);
    println!("{}", table.render());
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("csv -> {path}");
    }
    Ok(())
}

/// `asyncmel serve` — the spool-watching daemon. The submission files
/// carry their own scenarios, so the global `--config` override does
/// not apply here.
fn cmd_serve(args: &Args) -> Result<()> {
    let opts = ServeOptions {
        spool: PathBuf::from(args.get("spool").unwrap_or("spool")),
        once: args.has("once"),
        poll_ms: args.get_or("poll-ms", 200u64)?,
        checkpoint_every: args.get_or("checkpoint-every", 0usize)?,
        stop_after_segments: match args.get("stop-after") {
            Some(_) => Some(args.require("stop-after")?),
            None => None,
        },
        format: args.get("format").unwrap_or("json").to_string(),
        stdin: args.has("stdin"),
    };
    let summary = asyncmel::serve::serve(&opts)?;
    println!(
        "serve: {} completed, {} failed, {} suspended, {} segment(s)",
        summary.jobs_completed, summary.jobs_failed, summary.jobs_suspended, summary.segments
    );
    Ok(())
}

/// `asyncmel trace-gen` — seeded churn-trace generators. Emits the
/// trace JSON schema `{"regions": R, "events": [{"t": S, ...}]}` that
/// `ScenarioConfig.trace` (and serve submissions) accept.
fn cmd_trace_gen(args: &Args) -> Result<()> {
    let kind = args.positional.first().map(|s| s.as_str()).unwrap_or("diurnal");
    let seed: u64 = args.get_or("seed", 1)?;
    let regions: usize = args.get_or("regions", 1)?;
    let trace = match kind {
        "diurnal" => TraceConfig::gen_diurnal(
            seed,
            args.get_or("horizon", 600.0)?,
            args.get_or("period", 300.0)?,
            args.get_or("steps", 16)?,
            args.get_or("base", 8)?,
            args.get_or("peak", 32)?,
            regions,
        ),
        "flash" => TraceConfig::gen_flash_crowd(
            seed,
            args.get_or("start", 60.0)?,
            args.get_or("steps", 5)?,
            args.get_or("joins", 10)?,
            args.get_or("hold", 120.0)?,
            regions,
        ),
        "outage" => TraceConfig::gen_regional_outages(
            seed,
            args.get_or("horizon", 600.0)?,
            args.get_or("outages", 3)?,
            args.get_or("fraction", 0.5)?,
            args.get_or("recover", 90.0)?,
            regions,
            args.get_or("alive", 32)?,
        ),
        other => bail!("unknown trace kind '{other}' (diurnal|flash|outage)"),
    };
    let text = trace.to_json().pretty();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("trace ({} events) -> {path}", trace.events.len());
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let base = base_config(&args)?;
    match args.subcommand.as_deref() {
        Some("info") => {
            cmd_info(&base);
            Ok(())
        }
        Some("solve") => cmd_solve(base, &args),
        Some("fig2") => cmd_fig2(base, &args),
        Some("fig3") => cmd_fig3(base, &args),
        Some("train") => cmd_train(base, &args),
        Some("fleet") => cmd_fleet(base, &args),
        Some("multi") => cmd_multi(base, &args),
        Some("ablation") => cmd_ablation(base, &args),
        Some("energy-sweep") => cmd_energy_sweep(base, &args),
        Some("serve") => cmd_serve(&args),
        Some("trace-gen") => cmd_trace_gen(&args),
        Some(other) => bail!("unknown subcommand '{other}'\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}
