//! Deterministic sharded thread pool for independent learner steps.
//!
//! `ExecMode::Real` runs caps out when every learner's `train_step` is
//! executed serially — the top scaling bottleneck for real-numerics
//! fleets (ROADMAP "shard the native executor across threads"). This
//! pool fans a batch of **independent** jobs out across `num_threads`
//! workers and hands the results back **indexed by job position**, so
//! the caller merges them in stable slot order and an N-thread run is
//! bit-identical to the single-thread run. Determinism is the repo's
//! core invariant (the lock-step orchestrator is the differential
//! oracle for the event engine), so the contract is explicit:
//!
//! * jobs must not share mutable state (they get `&` world views only);
//! * all RNG draws happen in the caller **before** the fan-out;
//! * results are returned as `Vec<T>` in job order, regardless of which
//!   worker finished first.
//!
//! The offline registry has no `rayon`, so the pool is built on
//! `std::thread::scope` + `mpsc` channels: workers claim contiguous
//! chunks of the job range from a shared atomic cursor (cheap dynamic
//! load balancing — learner costs are heterogeneous by construction)
//! and stream `(index, result)` pairs back to the caller, which slots
//! them into place. Threads live only for the duration of one batch;
//! at the O(ms) cost of a learner train step the spawn overhead is
//! noise, and scoped threads let jobs borrow the engine's world
//! directly (no `Arc`, no `'static` bounds).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::Result;

/// A deterministic fork-join pool over `num_threads` workers.
#[derive(Debug, Clone)]
pub struct ThreadPool {
    threads: usize,
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::serial()
    }
}

impl ThreadPool {
    /// Build a pool with `num_threads` workers; `0` means "use the
    /// machine's available parallelism" (the `ScenarioConfig.num_threads
    /// = 0` convention).
    pub fn new(num_threads: usize) -> Self {
        let threads = if num_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            num_threads
        };
        Self { threads }
    }

    /// A single-worker pool: every `map` runs inline on the caller.
    pub fn serial() -> Self {
        Self { threads: 1 }
    }

    /// Worker count this pool fans out to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Evaluate `f(0..n)` and return the results in index order.
    ///
    /// With one worker (or `n <= 1`) this is a plain serial loop — the
    /// fan-out path must produce the exact same `Vec`, which the
    /// determinism tests assert end-to-end through both engines.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.threads.min(n);
        // Chunked claiming: big enough to amortize the atomic + channel
        // traffic, small enough that heterogeneous job costs still
        // balance (~4 claims per worker).
        let chunk = (n / (workers * 4)).max(1);
        let cursor = AtomicUsize::new(0);
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let cursor = &cursor;
                let f = &f;
                scope.spawn(move || loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        if tx.send((i, f(i))).is_err() {
                            return; // receiver gone — batch abandoned
                        }
                    }
                });
            }
            drop(tx); // the receive loop ends when every worker is done
            for (i, v) in rx {
                out[i] = Some(v);
            }
        });
        out.into_iter()
            .map(|v| v.expect("pool worker delivered every index"))
            .collect()
    }

    /// Fallible [`Self::map`]: runs every job, then surfaces the first
    /// error **in job order** (deterministic — not "whichever worker
    /// failed first on the wall clock").
    pub fn try_map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        self.map(n, f).into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_threads_means_available_parallelism() {
        assert!(ThreadPool::new(0).threads() >= 1);
        assert_eq!(ThreadPool::new(3).threads(), 3);
        assert_eq!(ThreadPool::serial().threads(), 1);
    }

    #[test]
    fn map_returns_results_in_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let pool = ThreadPool::new(threads);
            let out = pool.map(257, |i| i * i);
            assert_eq!(out.len(), 257);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads={threads} index {i}");
            }
        }
    }

    #[test]
    fn map_matches_serial_for_uneven_job_costs() {
        // heterogeneous job durations must not reorder results
        let serial: Vec<u64> = ThreadPool::serial().map(64, |i| {
            std::hint::black_box((0..(i % 7) * 1000).sum::<usize>());
            (i as u64) * 31
        });
        let sharded = ThreadPool::new(8).map(64, |i| {
            std::hint::black_box((0..(i % 7) * 1000).sum::<usize>());
            (i as u64) * 31
        });
        assert_eq!(serial, sharded);
    }

    #[test]
    fn map_handles_empty_and_singleton_batches() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn try_map_surfaces_the_first_error_in_job_order() {
        let pool = ThreadPool::new(4);
        let err = pool
            .try_map(100, |i| {
                if i == 23 || i == 71 {
                    Err(anyhow::anyhow!("job {i} failed"))
                } else {
                    Ok(i)
                }
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "job 23 failed");
        let ok = pool.try_map(10, |i| Ok(i * 2)).unwrap();
        assert_eq!(ok, (0..20).step_by(2).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_can_borrow_the_caller_world() {
        let world: Vec<f64> = (0..50).map(|i| i as f64 * 0.5).collect();
        let pool = ThreadPool::new(4);
        let out = pool.map(world.len(), |i| world[i] * 2.0);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }
}
