//! Deterministic sharded thread pool for independent learner steps.
//!
//! `ExecMode::Real` runs caps out when every learner's `train_step` is
//! executed serially — the top scaling bottleneck for real-numerics
//! fleets (ROADMAP "shard the native executor across threads"). This
//! pool fans a batch of **independent** jobs out across `num_threads`
//! workers and hands the results back **indexed by job position**, so
//! the caller merges them in stable slot order and an N-thread run is
//! bit-identical to the single-thread run. Determinism is the repo's
//! core invariant (the lock-step orchestrator is the differential
//! oracle for the event engine), so the contract is explicit:
//!
//! * jobs must not share mutable state (they get `&` world views only);
//! * all RNG draws happen in the caller **before** the fan-out;
//! * results are returned as `Vec<T>` in job order, regardless of which
//!   worker finished first.
//!
//! The offline registry has no `rayon`, so the pool is hand-rolled:
//! **persistent** workers are spawned once (lazily, on the first
//! fan-out) and parked on a condvar between batches. Publishing a batch
//! bumps a generation counter and unparks everyone; workers then claim
//! contiguous chunks of the job range from a shared atomic cursor
//! (cheap dynamic load balancing — learner costs are heterogeneous by
//! construction) and write `(index, result)` pairs straight into the
//! caller's output slots. With the ε-window arrival coalescing and the
//! tiled native backend, per-batch work dropped to the point where the
//! old spawn-per-batch `std::thread::scope` design was measurable
//! overhead (ROADMAP "long-lived pool + work queue") — the persistent
//! pool amortizes the spawn to once per engine run.
//!
//! Callers still borrow the engine world without `Arc` or `'static`
//! bounds: [`ThreadPool::scoped_batch`] type-erases the batch closure
//! behind a raw pointer and blocks until every worker has finished it,
//! so the borrow provably outlives all uses (the same guarantee
//! `std::thread::scope` gave, now without the per-batch spawn). Clones
//! of a `ThreadPool` share one worker set — the multi-model engine
//! runs `M` models over a single pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use anyhow::{ensure, Result};

/// Type-erased pointer to the batch closure currently published to the
/// workers. Validity is guaranteed by the completion barrier in
/// [`ThreadPool::scoped_batch`]: the caller cannot return (and so the
/// borrow cannot end) until every worker has finished running it.
struct Job(*const (dyn Fn() + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are
// fine) and `scoped_batch` keeps it alive for the whole batch.
unsafe impl Send for Job {}

/// Erase the borrow lifetime of a batch closure so it can sit in the
/// shared worker state.
///
/// # Safety
/// The caller must keep the closure alive (and its captures borrowed)
/// until every worker has finished running it — `scoped_batch`'s
/// completion barrier provides exactly that.
unsafe fn erase_job<'a>(f: &'a (dyn Fn() + Sync + 'a)) -> *const (dyn Fn() + Sync + 'static) {
    std::mem::transmute(f)
}

struct State {
    /// The published batch closure (`None` between batches).
    job: Option<Job>,
    /// Batch generation counter: bumped once per published batch so
    /// every worker runs each batch exactly once.
    epoch: u64,
    /// Workers still running the current batch.
    active: usize,
    /// A worker panicked inside the current batch.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between batches.
    work: Condvar,
    /// The publishing caller parks here until `active` drains to 0.
    done: Condvar,
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.as_ref().expect("published batch carries a job").0;
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        // SAFETY: `scoped_batch` blocks until `active` reaches 0, so
        // the closure behind the pointer outlives this call.
        let f = unsafe { &*job };
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        let mut st = shared.state.lock().unwrap();
        if outcome.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_one();
        }
    }
}

/// The long-lived background workers (`threads - 1` of them — the
/// caller itself is the last participant of every batch).
struct Workers {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Workers {
    fn spawn(n: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                epoch: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..n)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name("asyncmel-pool".into())
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, handles }
    }
}

impl Drop for Workers {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Waits out the in-flight batch even if the caller's own share of the
/// work panics — the workers must not outlive the borrow they run on.
struct BatchGuard<'a>(&'a Shared);

impl Drop for BatchGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        while st.active > 0 {
            st = self.0.done.wait(st).unwrap();
        }
        st.job = None;
    }
}

/// Writes batch results into disjoint output slots from many threads.
/// Each index is claimed by exactly one worker (atomic cursor), and the
/// caller only reads after the completion barrier.
struct SlotWriter<T>(*mut Option<T>);

// SAFETY: workers write disjoint indices; the mutex hand-off in
// `scoped_batch` sequences those writes before the caller's reads.
unsafe impl<T: Send> Send for SlotWriter<T> {}
unsafe impl<T: Send> Sync for SlotWriter<T> {}

/// A deterministic fork-join pool over `num_threads` persistent workers.
pub struct ThreadPool {
    threads: usize,
    /// Lazily-spawned shared worker set (`threads - 1` background
    /// threads); clones share it, serial pools never populate it.
    workers: Arc<OnceLock<Workers>>,
}

impl Clone for ThreadPool {
    fn clone(&self) -> Self {
        Self { threads: self.threads, workers: Arc::clone(&self.workers) }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("threads", &self.threads)
            .field("spawned", &self.workers.get().is_some())
            .finish()
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::serial()
    }
}

impl ThreadPool {
    /// Build a pool with `num_threads` workers; `0` means "use the
    /// machine's available parallelism" (the `ScenarioConfig.num_threads
    /// = 0` convention). Workers spawn lazily on the first fan-out and
    /// persist until the last clone of the pool drops.
    pub fn new(num_threads: usize) -> Self {
        let threads = if num_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            num_threads
        };
        Self { threads, workers: Arc::new(OnceLock::new()) }
    }

    /// A single-worker pool: every `map` runs inline on the caller.
    pub fn serial() -> Self {
        Self { threads: 1, workers: Arc::new(OnceLock::new()) }
    }

    /// Worker count this pool fans out to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` concurrently on every worker **and** the caller, then
    /// return once all of them have finished. `f` typically contains a
    /// claim loop over a shared atomic cursor (see [`Self::map`]); it
    /// may borrow the caller's stack freely — the completion barrier
    /// guarantees the borrow outlives every use, which is what lets
    /// engine code hand the pool `&`-views of its world without `Arc`.
    ///
    /// With one thread this is a plain inline call. Re-entrant use from
    /// inside a batch of the *same* pool is a bug and panics.
    pub fn scoped_batch<F: Fn() + Sync>(&self, f: F) {
        if self.threads <= 1 {
            f();
            return;
        }
        let workers = self
            .workers
            .get_or_init(|| Workers::spawn(self.threads - 1));
        let shared = &*workers.shared;
        {
            let mut st = shared.state.lock().unwrap();
            assert!(
                st.active == 0 && st.job.is_none(),
                "nested scoped_batch on one pool is not supported"
            );
            // SAFETY: the barrier below keeps the borrow alive until
            // every worker is done with it.
            st.job = Some(Job(unsafe { erase_job(&f) }));
            st.epoch = st.epoch.wrapping_add(1);
            st.active = workers.handles.len();
            st.panicked = false;
        }
        shared.work.notify_all();
        let guard = BatchGuard(shared);
        f(); // the caller is the last participant
        drop(guard); // barrier: wait for every worker
        let panicked = shared.state.lock().unwrap().panicked;
        if panicked {
            panic!("pool worker panicked during a batch");
        }
    }

    /// Evaluate `f(0..n)` and return the results in index order.
    ///
    /// With one worker (or `n <= 1`) this is a plain serial loop — the
    /// fan-out path must produce the exact same `Vec`, which the
    /// determinism tests assert end-to-end through both engines.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.threads.min(n);
        // Chunked claiming: big enough to amortize the atomic traffic,
        // small enough that heterogeneous job costs still balance
        // (~4 claims per worker).
        let chunk = (n / (workers * 4)).max(1);
        let cursor = AtomicUsize::new(0);
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let slots = SlotWriter(out.as_mut_ptr());
        self.scoped_batch(|| {
            let slots = &slots;
            loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    // SAFETY: each index is claimed exactly once via
                    // the atomic cursor, so writes are disjoint; the
                    // caller reads only after the completion barrier.
                    unsafe { slots.0.add(i).write(Some(f(i))) };
                }
            }
        });
        out.into_iter()
            .map(|v| v.expect("pool worker delivered every index"))
            .collect()
    }

    /// Fallible [`Self::map`]: runs every job, then surfaces the first
    /// error **in job order** (deterministic — not "whichever worker
    /// failed first on the wall clock").
    pub fn try_map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        self.map(n, f).into_iter().collect()
    }

    /// Batched fan-out shape: split `n` items into contiguous
    /// `chunk`-sized ranges, run `f(lo, hi)` per range (each job returns
    /// the results for items `lo..hi`, in order) and hand back the
    /// flattened `Vec` in item order. This is the shape the batched
    /// `train_many` flush and pooled evaluation use — one job amortizes
    /// a warmed scratch (or one batched kernel invocation) over its
    /// whole range instead of paying per-item setup. Error selection
    /// follows [`Self::try_map`]: first failing *chunk* in range order.
    pub fn try_map_chunked<T, F>(&self, n: usize, chunk: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, usize) -> Result<Vec<T>> + Sync,
    {
        let chunk = chunk.max(1);
        let jobs = n.div_ceil(chunk);
        let parts = self.try_map(jobs, |j| {
            let lo = j * chunk;
            let hi = (lo + chunk).min(n);
            let out = f(lo, hi)?;
            ensure!(
                out.len() == hi - lo,
                "chunked job [{lo}, {hi}) returned {} results",
                out.len()
            );
            Ok(out)
        })?;
        Ok(parts.into_iter().flatten().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_threads_means_available_parallelism() {
        assert!(ThreadPool::new(0).threads() >= 1);
        assert_eq!(ThreadPool::new(3).threads(), 3);
        assert_eq!(ThreadPool::serial().threads(), 1);
    }

    #[test]
    fn map_returns_results_in_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let pool = ThreadPool::new(threads);
            let out = pool.map(257, |i| i * i);
            assert_eq!(out.len(), 257);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads={threads} index {i}");
            }
        }
    }

    #[test]
    fn map_matches_serial_for_uneven_job_costs() {
        // heterogeneous job durations must not reorder results
        let serial: Vec<u64> = ThreadPool::serial().map(64, |i| {
            std::hint::black_box((0..(i % 7) * 1000).sum::<usize>());
            (i as u64) * 31
        });
        let sharded = ThreadPool::new(8).map(64, |i| {
            std::hint::black_box((0..(i % 7) * 1000).sum::<usize>());
            (i as u64) * 31
        });
        assert_eq!(serial, sharded);
    }

    #[test]
    fn map_handles_empty_and_singleton_batches() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn try_map_surfaces_the_first_error_in_job_order() {
        let pool = ThreadPool::new(4);
        let err = pool
            .try_map(100, |i| {
                if i == 23 || i == 71 {
                    Err(anyhow::anyhow!("job {i} failed"))
                } else {
                    Ok(i)
                }
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "job 23 failed");
        let ok = pool.try_map(10, |i| Ok(i * 2)).unwrap();
        assert_eq!(ok, (0..20).step_by(2).collect::<Vec<_>>());
    }

    #[test]
    fn try_map_chunked_flattens_in_item_order() {
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            for (n, chunk) in [(0usize, 3usize), (1, 3), (7, 3), (9, 3), (64, 5), (10, 100)] {
                let out = pool
                    .try_map_chunked(n, chunk, |lo, hi| Ok((lo..hi).map(|i| i * 7).collect()))
                    .unwrap();
                let expect: Vec<usize> = (0..n).map(|i| i * 7).collect();
                assert_eq!(out, expect, "threads={threads} n={n} chunk={chunk}");
            }
        }
    }

    #[test]
    fn try_map_chunked_rejects_short_chunks_and_surfaces_errors() {
        let pool = ThreadPool::new(4);
        let err = pool
            .try_map_chunked(10, 4, |lo, hi| {
                if lo == 4 {
                    Err(anyhow::anyhow!("chunk at {lo} failed"))
                } else {
                    Ok((lo..hi).collect())
                }
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "chunk at 4 failed");
        let err = pool
            .try_map_chunked(10, 4, |lo, _hi| Ok(vec![lo]))
            .unwrap_err();
        assert!(err.to_string().contains("returned 1 results"), "{err}");
    }

    #[test]
    fn jobs_can_borrow_the_caller_world() {
        let world: Vec<f64> = (0..50).map(|i| i as f64 * 0.5).collect();
        let pool = ThreadPool::new(4);
        let out = pool.map(world.len(), |i| world[i] * 2.0);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }

    #[test]
    fn workers_persist_across_many_interleaved_batches() {
        // the persistent pool must survive arbitrary batch-size
        // interleavings — including the empty and singleton batches
        // that never touch the workers — without respawning
        let pool = ThreadPool::new(4);
        for round in 0..5usize {
            for n in [0usize, 1, 2, 3, 17, 1, 0, 64, 257, 5] {
                let out = pool.map(n, |i| i * 3 + round);
                let expect: Vec<usize> = (0..n).map(|i| i * 3 + round).collect();
                assert_eq!(out, expect, "round {round}, n {n}");
            }
        }
        // workers were actually spawned (some batch exceeded 1 job)
        assert!(pool.workers.get().is_some());
    }

    #[test]
    fn clones_share_one_worker_set() {
        let pool = ThreadPool::new(3);
        let clone = pool.clone();
        let a = clone.map(40, |i| i + 1);
        assert_eq!(a, (1..41).collect::<Vec<_>>());
        // the original now sees the workers the clone spawned
        assert!(pool.workers.get().is_some());
        let b = pool.map(40, |i| i + 2);
        assert_eq!(b, (2..42).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_batch_runs_on_all_participants_and_borrows() {
        let pool = ThreadPool::new(4);
        let counter = AtomicUsize::new(0);
        let data: Vec<usize> = (0..100).collect();
        pool.scoped_batch(|| {
            // every participant (3 workers + caller) runs this once
            counter.fetch_add(data.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4 * 100);
    }

    #[test]
    fn worker_panic_propagates_to_the_caller() {
        let pool = ThreadPool::new(4);
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence expected panics
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(64, |i| {
                if i == 33 {
                    panic!("boom");
                }
                i
            })
        }));
        std::panic::set_hook(hook);
        assert!(result.is_err(), "a panicking job must fail the batch");
        // and the pool is still usable afterwards
        assert_eq!(pool.map(8, |i| i), (0..8).collect::<Vec<_>>());
    }
}
