//! Model executor — the [`Executor`] trait seam between the
//! coordinator layer and the numerics backends.
//!
//! The hot path is [`Runtime::train_epochs_into`] /
//! [`Runtime::train_many`] / [`Runtime::evaluate`], consumed by the
//! coordinator layer through [`Runtime`]'s thin delegating wrappers.
//! The backend behind them is a `Box<dyn Executor>` — a public
//! object-safe trait with **borrow-first** entry points (caller-owned
//! parameters + scratch, no clone-and-return) — with two
//! implementations:
//!
//! * **native** (default): [`native::NativeExecutor`], an in-process
//!   f32 implementation of the same ReLU-MLP + softmax-CE train/eval
//!   steps the AOT artifacts encode. Hermetic — no registry, no
//!   artifact files. Construct directly with [`Runtime::native`], or
//!   let [`Runtime::load`] build it from an artifact `manifest.json`.
//!   The only backend implementing batched [`Executor::train_many`].
//! * **pjrt** (`--features pjrt`, requires the external `xla = "0.1.6"`
//!   crate): the original compiled-HLO path (per /opt/xla-example/
//!   load_hlo): HLO **text** → `HloModuleProto::from_text_file` →
//!   `XlaComputation` → `PjRtClient::cpu().compile` — once, at startup.
//!   Python never runs here. `train_many` is `Unsupported`
//!   ([`Executor::supports_train_many`] is `false`), so [`Runtime`]
//!   falls back to the per-task loop.

pub mod native;
pub mod pool;
pub mod spec;

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};

use crate::aggregation::ParamSet;
use crate::data::{Batch, Dataset, Minibatches};
use crate::sim::Rng;
pub use native::{BatchScratch, Scratch};
pub use pool::ThreadPool;
pub use spec::Manifest;

/// One unit of batched training work for [`Executor::train_many`]: a
/// learner's starting snapshot, its sample shard and its local epoch
/// count. The dataset, minibatch size and learning rate are shared per
/// call.
#[derive(Debug, Clone, Copy)]
pub struct TrainTask<'a> {
    /// The global parameters the learner trains from (its received
    /// snapshot — borrowed, the outcome owns the trained copy).
    pub params: &'a ParamSet,
    /// Sample indices of the learner's shard.
    pub shard: &'a [u32],
    /// Local epochs `τ` (0 = return the snapshot untouched, NaN loss).
    pub tau: u64,
}

/// Result of one [`TrainTask`]: the trained parameters and the final
/// local epoch's mean loss (NaN when no step ran).
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub params: ParamSet,
    pub train_loss: f32,
}

/// Object-safe backend seam for model execution — the redesign that
/// replaced the closed `Backend` enum + per-method `match`.
///
/// Entry points are **borrow-first**: the caller owns the parameter
/// buffer and the [`Scratch`] working memory, so a τ-epoch learner
/// round performs no backend-imposed allocation. [`Runtime`] keeps the
/// old allocating signatures as thin delegating wrappers, so call
/// sites outside `runtime/` keep compiling; new code (and the engine
/// flush paths) should call the borrow-first forms.
pub trait Executor: Send + Sync {
    /// Backend platform string (diagnostics).
    fn platform(&self) -> String;

    /// One SGD minibatch step in place; returns the masked mean loss.
    fn train_step_into(
        &self,
        s: &mut Scratch,
        params: &mut ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> Result<f32>;

    /// `tau` local epochs of minibatch SGD over a shard, updating
    /// `params` in place; returns the last epoch's mean loss (NaN when
    /// no step ran).
    fn train_epochs_into(
        &self,
        s: &mut Scratch,
        params: &mut ParamSet,
        data: &Dataset,
        shard: &[u32],
        tau: u64,
        train_batch: usize,
        lr: f32,
    ) -> Result<f32>;

    /// Batched τ-epoch SGD over a **uniform** batch of tasks (same τ,
    /// same shard length) — the coalesced-flush hot path. Backends
    /// without a batched kernel return an `Unsupported` error and
    /// advertise it via [`Self::supports_train_many`]; callers should
    /// go through [`Runtime::train_many`], which splits mixed batches
    /// into uniform runs and falls back per task.
    fn train_many(
        &self,
        tasks: &[TrainTask<'_>],
        data: &Dataset,
        train_batch: usize,
        lr: f32,
    ) -> Result<Vec<TrainOutcome>>;

    /// Whether [`Self::train_many`] is implemented (`false` routes
    /// [`Runtime::train_many`] to the per-task fallback).
    fn supports_train_many(&self) -> bool {
        true
    }

    /// One eval minibatch through a caller-held scratch:
    /// `(correct, loss_sum, mask_sum)` over the real rows.
    fn evaluate_scratch(
        &self,
        s: &mut Scratch,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<(f64, f64, f64)>;
}

impl Executor for native::NativeExecutor {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn train_step_into(
        &self,
        s: &mut Scratch,
        params: &mut ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        Ok(native::NativeExecutor::train_step_into(self, s, params, batch, lr))
    }

    fn train_epochs_into(
        &self,
        s: &mut Scratch,
        params: &mut ParamSet,
        data: &Dataset,
        shard: &[u32],
        tau: u64,
        train_batch: usize,
        lr: f32,
    ) -> Result<f32> {
        let mut last_loss = f32::NAN;
        for _epoch in 0..tau {
            let mut loss_sum = 0.0f64;
            let mut batches = 0usize;
            for batch in Minibatches::new(data, shard, train_batch) {
                let loss = native::NativeExecutor::train_step_into(self, s, params, &batch, lr);
                loss_sum += loss as f64;
                batches += 1;
            }
            if batches > 0 {
                last_loss = (loss_sum / batches as f64) as f32;
            }
        }
        Ok(last_loss)
    }

    fn train_many(
        &self,
        tasks: &[TrainTask<'_>],
        data: &Dataset,
        train_batch: usize,
        lr: f32,
    ) -> Result<Vec<TrainOutcome>> {
        native::NativeExecutor::train_many(self, tasks, data, train_batch, lr)
    }

    fn evaluate_scratch(
        &self,
        s: &mut Scratch,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<(f64, f64, f64)> {
        Ok(self.eval_batch_with(s, params, batch))
    }
}

/// Compiled artifacts (or the native engine) behind the [`Executor`]
/// seam, bundled with the model [`Manifest`]. The coordinator layer
/// talks to this; backends are swapped by constructing with
/// [`Runtime::load`] (feature-selected) or [`Runtime::native`].
pub struct Runtime {
    executor: Box<dyn Executor>,
    pub manifest: Manifest,
    pub artifacts_dir: PathBuf,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("artifacts_dir", &self.artifacts_dir)
            .finish()
    }
}

/// Result of an evaluation pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub accuracy: f64,
    pub mean_loss: f64,
    pub samples: u64,
}

impl Runtime {
    /// Load artifacts from `dir`: the manifest always; under the `pjrt`
    /// feature also the compiled HLO entry points. The default build
    /// runs the native executor on the manifest's `layer_dims`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        #[cfg(feature = "pjrt")]
        let executor: Box<dyn Executor> = Box::new(PjrtBackend::load(&dir, &manifest)?);
        #[cfg(not(feature = "pjrt"))]
        let executor: Box<dyn Executor> =
            Box::new(native::NativeExecutor::new(&manifest.layer_dims));
        Ok(Self { executor, manifest, artifacts_dir: dir })
    }

    /// Build an artifact-free native runtime for the given model stack —
    /// the path tests and the event engine use to run real numerics
    /// without `make artifacts`.
    pub fn native(layer_dims: &[usize], train_batch: usize, eval_batch: usize) -> Self {
        let manifest = Manifest::native(layer_dims, train_batch, eval_batch);
        Self {
            executor: Box::new(native::NativeExecutor::new(layer_dims)),
            manifest,
            artifacts_dir: PathBuf::from("<native>"),
        }
    }

    /// Backend platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.executor.platform()
    }

    /// Borrow the backend through the [`Executor`] seam — for callers
    /// that manage their own parameter buffers and [`Scratch`].
    pub fn executor(&self) -> &dyn Executor {
        &*self.executor
    }

    /// He-initialized parameter set matching the manifest shapes.
    pub fn init_params(&self, rng: &mut Rng) -> ParamSet {
        self.manifest
            .param_shapes()
            .iter()
            .map(|shape| {
                let n: usize = shape.iter().product();
                if shape.len() == 2 {
                    // He-normal for ReLU stacks: std = sqrt(2 / fan_in)
                    let std = (2.0 / shape[0] as f64).sqrt();
                    (0..n).map(|_| rng.normal_ms(0.0, std) as f32).collect()
                } else {
                    vec![0.0f32; n] // biases at zero
                }
            })
            .collect()
    }

    /// One SGD minibatch step: returns the updated parameters + loss.
    ///
    /// **Deprecated in practice** (kept for compatibility, not removed):
    /// this is the allocating clone-and-return shape — it clones the
    /// parameter buffer and builds a fresh [`Scratch`] per call. New
    /// code should hold a [`Scratch`] and call
    /// [`Executor::train_step_into`] via [`Self::executor`] instead.
    pub fn train_step(
        &self,
        params: &ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> Result<(ParamSet, f32)> {
        let mut local = params.clone();
        let mut scratch = Scratch::new();
        let loss = self.executor.train_step_into(&mut scratch, &mut local, batch, lr)?;
        Ok((local, loss))
    }

    /// Borrow-first `tau`-epoch loop: `params` updated in place through
    /// a caller-owned [`Scratch`]; returns the last epoch's mean loss.
    /// This is the engine's per-learner hot path (zero-alloc on the
    /// native backend).
    pub fn train_epochs_into(
        &self,
        scratch: &mut Scratch,
        params: &mut ParamSet,
        data: &Dataset,
        shard: &[u32],
        tau: u64,
        lr: f32,
    ) -> Result<f32> {
        self.executor.train_epochs_into(
            scratch,
            params,
            data,
            shard,
            tau,
            self.manifest.train_batch,
            lr,
        )
    }

    /// `tau` local epochs of minibatch SGD over a shard; returns the
    /// final local parameters and the last epoch's mean loss.
    ///
    /// Thin clone-and-return wrapper over [`Self::train_epochs_into`];
    /// callers that recycle buffers across rounds should use the
    /// borrow-first form directly.
    pub fn train_epochs(
        &self,
        params: &ParamSet,
        data: &Dataset,
        shard: &[u32],
        tau: u64,
        lr: f32,
    ) -> Result<(ParamSet, f32)> {
        let mut local = params.clone();
        let mut scratch = Scratch::new();
        let loss = self.train_epochs_into(&mut scratch, &mut local, data, shard, tau, lr)?;
        Ok((local, loss))
    }

    /// Batched τ-epoch SGD over a flush's worth of learner tasks.
    ///
    /// Tasks are grouped by `(tau, shard length)` (preserving first-seen
    /// order) and each uniform group runs through the backend's
    /// [`Executor::train_many`] batched kernels; mixed-shape flushes
    /// therefore split into several batched runs rather than falling
    /// back to scalar code. Backends without batched kernels
    /// ([`Executor::supports_train_many`] = `false`, e.g. pjrt) fall
    /// back to a per-task [`Executor::train_epochs_into`] loop through
    /// one recycled [`Scratch`]. Outcomes are returned in task order
    /// and are bitwise identical to the per-learner path in the default
    /// build.
    pub fn train_many(
        &self,
        tasks: &[TrainTask<'_>],
        data: &Dataset,
        lr: f32,
    ) -> Result<Vec<TrainOutcome>> {
        let b = self.manifest.train_batch;
        if !self.executor.supports_train_many() {
            let mut scratch = Scratch::new();
            let mut outs = Vec::with_capacity(tasks.len());
            for t in tasks {
                let mut local = t.params.clone();
                let loss = self.executor.train_epochs_into(
                    &mut scratch, &mut local, data, t.shard, t.tau, b, lr,
                )?;
                outs.push(TrainOutcome { params: local, train_loss: loss });
            }
            return Ok(outs);
        }
        // Group into uniform (tau, shard-length) runs, preserving
        // first-seen order; scatter outcomes back by original index.
        let mut groups: Vec<((u64, usize), Vec<usize>)> = Vec::new();
        for (i, t) in tasks.iter().enumerate() {
            let key = (t.tau, t.shard.len());
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((key, vec![i])),
            }
        }
        let mut outs: Vec<Option<TrainOutcome>> = (0..tasks.len()).map(|_| None).collect();
        for (_, idxs) in &groups {
            let group: Vec<TrainTask<'_>> = idxs.iter().map(|&i| tasks[i]).collect();
            let got = self.executor.train_many(&group, data, b, lr)?;
            ensure!(
                got.len() == group.len(),
                "train_many returned {} outcomes for {} tasks",
                got.len(),
                group.len()
            );
            for (&i, o) in idxs.iter().zip(got) {
                outs[i] = Some(o);
            }
        }
        Ok(outs.into_iter().map(|o| o.expect("every task grouped")).collect())
    }

    /// Streamed evaluation over a whole dataset. One [`Scratch`] is
    /// recycled across all eval batches.
    pub fn evaluate(&self, params: &ParamSet, data: &Dataset) -> Result<EvalResult> {
        let idx: Vec<u32> = (0..data.len() as u32).collect();
        let mut correct = 0.0;
        let mut loss = 0.0;
        let mut n = 0.0;
        let mut scratch = Scratch::new();
        for batch in Minibatches::new(data, &idx, self.manifest.eval_batch) {
            let (c, l, m) = self.executor.evaluate_scratch(&mut scratch, params, &batch)?;
            correct += c;
            loss += l;
            n += m;
        }
        ensure!(n > 0.0, "empty evaluation set");
        Ok(EvalResult {
            accuracy: correct / n,
            mean_loss: loss / n,
            samples: n as u64,
        })
    }

    /// [`Self::evaluate`] with the eval minibatches fanned out across a
    /// [`ThreadPool`]. Batches are split into contiguous chunks (one
    /// recycled [`Scratch`] per chunk, so fan-out stays alloc-light) and
    /// per-batch results are reduced in batch order, so the outcome is
    /// **bit-identical** to the serial path for any thread count (the
    /// pool's core contract).
    pub fn evaluate_pooled(
        &self,
        pool: &ThreadPool,
        params: &ParamSet,
        data: &Dataset,
    ) -> Result<EvalResult> {
        if pool.threads() <= 1 {
            return self.evaluate(params, data);
        }
        let idx: Vec<u32> = (0..data.len() as u32).collect();
        let batches: Vec<Batch> =
            Minibatches::new(data, &idx, self.manifest.eval_batch).collect();
        let chunk = batches
            .len()
            .div_ceil(pool.threads().saturating_mul(4).max(1))
            .max(1);
        let parts = pool.try_map_chunked(batches.len(), chunk, |lo, hi| {
            let mut scratch = Scratch::new();
            let mut triples = Vec::with_capacity(hi - lo);
            for batch in &batches[lo..hi] {
                triples.push(self.executor.evaluate_scratch(&mut scratch, params, batch)?);
            }
            Ok(triples)
        })?;
        let (mut correct, mut loss, mut n) = (0.0, 0.0, 0.0);
        for (c, l, m) in parts {
            correct += c;
            loss += l;
            n += m;
        }
        ensure!(n > 0.0, "empty evaluation set");
        Ok(EvalResult {
            accuracy: correct / n,
            mean_loss: loss / n,
            samples: n as u64,
        })
    }
}

/// The compiled-HLO PJRT backend (original execution path).
#[cfg(feature = "pjrt")]
struct PjrtBackend {
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    /// Own copy of the model manifest — the object-safe [`Executor`]
    /// entry points can't thread `Runtime.manifest` through.
    manifest: Manifest,
}

#[cfg(feature = "pjrt")]
impl Executor for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn train_step_into(
        &self,
        _s: &mut Scratch,
        params: &mut ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        // Device buffers round-trip through literals; the scratch is a
        // host-side concept, unused here.
        let (next, loss) = PjrtBackend::train_step(self, &self.manifest, params, batch, lr)?;
        *params = next;
        Ok(loss)
    }

    fn train_epochs_into(
        &self,
        s: &mut Scratch,
        params: &mut ParamSet,
        data: &Dataset,
        shard: &[u32],
        tau: u64,
        train_batch: usize,
        lr: f32,
    ) -> Result<f32> {
        let mut last_loss = f32::NAN;
        for _epoch in 0..tau {
            let mut loss_sum = 0.0f64;
            let mut batches = 0usize;
            for batch in Minibatches::new(data, shard, train_batch) {
                let loss = Executor::train_step_into(self, s, params, &batch, lr)?;
                loss_sum += loss as f64;
                batches += 1;
            }
            if batches > 0 {
                last_loss = (loss_sum / batches as f64) as f32;
            }
        }
        Ok(last_loss)
    }

    fn train_many(
        &self,
        _tasks: &[TrainTask<'_>],
        _data: &Dataset,
        _train_batch: usize,
        _lr: f32,
    ) -> Result<Vec<TrainOutcome>> {
        bail!("train_many is unsupported on the pjrt backend; use the per-task fallback")
    }

    fn supports_train_many(&self) -> bool {
        false
    }

    fn evaluate_scratch(
        &self,
        _s: &mut Scratch,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<(f64, f64, f64)> {
        PjrtBackend::eval_batch(self, &self.manifest, params, batch)
    }
}

#[cfg(feature = "pjrt")]
fn literal_from_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    ensure!(n == data.len(), "literal data {} != shape {:?}", data.len(), shape);
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .context("reshaping literal")
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Load and compile both entry points from the artifact dir.
    fn load(dir: &Path, manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("utf-8 path"),
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        };
        let train_exe = load(&manifest.entries.train_step.file)?;
        let eval_exe = load(&manifest.entries.eval_step.file)?;
        Ok(Self { client, train_exe, eval_exe, manifest: manifest.clone() })
    }

    fn param_literals(&self, manifest: &Manifest, params: &ParamSet) -> Result<Vec<xla::Literal>> {
        let shapes = manifest.param_shapes();
        ensure!(params.len() == shapes.len(), "param tensor count mismatch");
        params
            .iter()
            .zip(&shapes)
            .map(|(p, s)| literal_from_f32(p, s))
            .collect()
    }

    /// Upload host literals as *self-owned* device buffers and run the
    /// executable via `execute_b`.
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal-taking variant): xla 0.1.6's C shim leaks every input
    /// buffer it creates there (`BufferFromHostLiteral(..).release()`
    /// with no reclaim — ~2 MB per train step, hundreds of MB/s in the
    /// training loop). With `execute_b` the inputs are `PjRtBuffer`s we
    /// own, freed on drop. See EXPERIMENTS.md §Perf.
    fn run_buffered(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|lit| self.client.buffer_from_host_literal(None, lit))
            .collect::<std::result::Result<_, _>>()
            .context("uploading input buffers")?;
        let out = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .context("executing artifact")?;
        ensure!(
            out.len() == 1 && out[0].len() == 1,
            "expected a single replica with a single tuple output"
        );
        Ok(out[0][0].to_literal_sync()?)
    }

    fn train_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> Result<(ParamSet, f32)> {
        let b = manifest.train_batch;
        let f = manifest.num_features();
        let c = manifest.num_classes();
        let mut inputs = self.param_literals(manifest, params)?;
        inputs.push(literal_from_f32(&batch.x, &[b, f])?);
        inputs.push(literal_from_f32(&batch.y_onehot, &[b, c])?);
        inputs.push(literal_from_f32(&batch.mask, &[b])?);
        inputs.push(xla::Literal::scalar(lr));

        let result = self
            .run_buffered(&self.train_exe, &inputs)
            .context("executing train_step")?;
        let outs = result.to_tuple().context("unpacking train_step tuple")?;
        ensure!(
            outs.len() == manifest.num_param_tensors + 1,
            "train_step returned {} outputs",
            outs.len()
        );
        let mut new_params: ParamSet = Vec::with_capacity(manifest.num_param_tensors);
        for lit in &outs[..manifest.num_param_tensors] {
            new_params.push(lit.to_vec::<f32>()?);
        }
        let loss = outs[manifest.num_param_tensors].to_vec::<f32>()?[0];
        Ok((new_params, loss))
    }

    fn eval_batch(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<(f64, f64, f64)> {
        let b = manifest.eval_batch;
        let f = manifest.num_features();
        let c = manifest.num_classes();
        let mut inputs = self.param_literals(manifest, params)?;
        inputs.push(literal_from_f32(&batch.x, &[b, f])?);
        inputs.push(literal_from_f32(&batch.y_onehot, &[b, c])?);
        inputs.push(literal_from_f32(&batch.mask, &[b])?);
        let result = self
            .run_buffered(&self.eval_exe, &inputs)
            .context("executing eval_step")?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 3, "eval_step returned {} outputs", outs.len());
        Ok((
            outs[0].to_vec::<f32>()?[0] as f64,
            outs[1].to_vec::<f32>()?[0] as f64,
            outs[2].to_vec::<f32>()?[0] as f64,
        ))
    }
}

/// Default artifact directory: `$ASYNCMEL_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("ASYNCMEL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

// NOTE: tests that need the compiled PJRT artifacts live in
// rust/tests/e2e_runtime.rs (they require `make artifacts` first and
// skip loudly otherwise); the native backend's numerics are unit-tested
// in [`native`].
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("ASYNCMEL_ARTIFACTS", "/tmp/zzz");
        assert_eq!(default_artifacts_dir(), PathBuf::from("/tmp/zzz"));
        std::env::remove_var("ASYNCMEL_ARTIFACTS");
        assert_eq!(default_artifacts_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn native_runtime_round_trips_init_and_eval() {
        let rt = Runtime::native(&[36, 16, 4], 32, 64);
        assert_eq!(rt.platform(), "native-cpu");
        rt.manifest.check().unwrap();
        let mut rng = Rng::new(3);
        let params = rt.init_params(&mut rng);
        let shapes = rt.manifest.param_shapes();
        assert_eq!(params.len(), shapes.len());
        for (p, s) in params.iter().zip(&shapes) {
            assert_eq!(p.len(), s.iter().product::<usize>());
        }
        // biases zero, weights non-degenerate
        assert!(params[1].iter().all(|&v| v == 0.0));
        assert!(params[0].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn pooled_evaluate_is_bit_identical_to_serial() {
        use crate::data::{synth, SynthConfig};
        let rt = Runtime::native(&[36, 16, 4], 32, 48);
        let ds = synth::generate(&SynthConfig {
            side: 6,
            classes: 4,
            train: 64,
            test: 200, // several eval batches incl. a padded tail
            ..SynthConfig::default()
        });
        let mut rng = Rng::new(9);
        let params = rt.init_params(&mut rng);
        let serial = rt.evaluate(&params, &ds.test).unwrap();
        for threads in [2usize, 3, 8] {
            let pooled = rt
                .evaluate_pooled(&ThreadPool::new(threads), &params, &ds.test)
                .unwrap();
            assert_eq!(serial, pooled, "threads={threads}");
        }
    }

    #[test]
    fn load_without_artifacts_errors() {
        let err = Runtime::load("/definitely/not/a/dir").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest.json"), "{msg}");
    }

    #[cfg(feature = "pjrt")]
    mod pjrt_literals {
        use super::super::*;

        #[test]
        fn literal_round_trips_shape() {
            let lit = literal_from_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
            assert_eq!(lit.element_count(), 6);
            assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        }

        #[test]
        fn literal_scalar() {
            let lit = literal_from_f32(&[7.5], &[]).unwrap();
            assert_eq!(lit.to_vec::<f32>().unwrap(), vec![7.5]);
        }

        #[test]
        fn literal_rejects_bad_length() {
            assert!(literal_from_f32(&[1.0, 2.0], &[3]).is_err());
        }
    }
}
