//! Model executor — dual-backend: pure-Rust native (default) or PJRT.
//!
//! The hot path is [`Runtime::train_step`] / [`Runtime::evaluate`],
//! consumed by the coordinator layer. Two interchangeable backends:
//!
//! * **native** (default): [`native::NativeExecutor`], an in-process
//!   f32 implementation of the same ReLU-MLP + softmax-CE train/eval
//!   steps the AOT artifacts encode. Hermetic — no registry, no
//!   artifact files. Construct directly with [`Runtime::native`], or
//!   let [`Runtime::load`] build it from an artifact `manifest.json`.
//! * **pjrt** (`--features pjrt`, requires the external `xla = "0.1.6"`
//!   crate): the original compiled-HLO path (per /opt/xla-example/
//!   load_hlo): HLO **text** → `HloModuleProto::from_text_file` →
//!   `XlaComputation` → `PjRtClient::cpu().compile` — once, at startup.
//!   Python never runs here.

pub mod native;
pub mod pool;
pub mod spec;

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::aggregation::ParamSet;
use crate::data::{Batch, Dataset, Minibatches};
use crate::sim::Rng;
pub use pool::ThreadPool;
pub use spec::Manifest;

/// Compiled artifacts (or the native engine) behind one interface.
pub struct Runtime {
    backend: Backend,
    pub manifest: Manifest,
    pub artifacts_dir: PathBuf,
}

enum Backend {
    Native(native::NativeExecutor),
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtBackend),
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("artifacts_dir", &self.artifacts_dir)
            .finish()
    }
}

/// Result of an evaluation pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub accuracy: f64,
    pub mean_loss: f64,
    pub samples: u64,
}

impl Runtime {
    /// Load artifacts from `dir`: the manifest always; under the `pjrt`
    /// feature also the compiled HLO entry points. The default build
    /// runs the native executor on the manifest's `layer_dims`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        #[cfg(feature = "pjrt")]
        let backend = Backend::Pjrt(PjrtBackend::load(&dir, &manifest)?);
        #[cfg(not(feature = "pjrt"))]
        let backend = Backend::Native(native::NativeExecutor::new(&manifest.layer_dims));
        Ok(Self { backend, manifest, artifacts_dir: dir })
    }

    /// Build an artifact-free native runtime for the given model stack —
    /// the path tests and the event engine use to run real numerics
    /// without `make artifacts`.
    pub fn native(layer_dims: &[usize], train_batch: usize, eval_batch: usize) -> Self {
        let manifest = Manifest::native(layer_dims, train_batch, eval_batch);
        Self {
            backend: Backend::Native(native::NativeExecutor::new(layer_dims)),
            manifest,
            artifacts_dir: PathBuf::from("<native>"),
        }
    }

    /// Backend platform string (diagnostics).
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Native(_) => "native-cpu".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.client.platform_name(),
        }
    }

    /// He-initialized parameter set matching the manifest shapes.
    pub fn init_params(&self, rng: &mut Rng) -> ParamSet {
        self.manifest
            .param_shapes()
            .iter()
            .map(|shape| {
                let n: usize = shape.iter().product();
                if shape.len() == 2 {
                    // He-normal for ReLU stacks: std = sqrt(2 / fan_in)
                    let std = (2.0 / shape[0] as f64).sqrt();
                    (0..n).map(|_| rng.normal_ms(0.0, std) as f32).collect()
                } else {
                    vec![0.0f32; n] // biases at zero
                }
            })
            .collect()
    }

    /// One SGD minibatch step: returns the updated parameters + loss.
    pub fn train_step(
        &self,
        params: &ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> Result<(ParamSet, f32)> {
        match &self.backend {
            Backend::Native(exec) => Ok(exec.train_step(params, batch, lr)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.train_step(&self.manifest, params, batch, lr),
        }
    }

    /// `tau` local epochs of minibatch SGD over a shard; returns the
    /// final local parameters and the last epoch's mean loss.
    ///
    /// On the native backend this is the zero-alloc hot loop: one
    /// parameter buffer updated in place and one [`native::Scratch`]
    /// recycled across every step of every epoch (bit-identical to the
    /// step-by-step path — see `runtime::native`).
    pub fn train_epochs(
        &self,
        params: &ParamSet,
        data: &Dataset,
        shard: &[u32],
        tau: u64,
        lr: f32,
    ) -> Result<(ParamSet, f32)> {
        let mut local = params.clone();
        let mut scratch = native::Scratch::new();
        let mut last_loss = f32::NAN;
        for _epoch in 0..tau {
            let mut loss_sum = 0.0f64;
            let mut batches = 0usize;
            for batch in Minibatches::new(data, shard, self.manifest.train_batch) {
                let loss = match &self.backend {
                    Backend::Native(exec) => {
                        exec.train_step_into(&mut scratch, &mut local, &batch, lr)
                    }
                    #[cfg(feature = "pjrt")]
                    Backend::Pjrt(_) => {
                        let (next, loss) = self.train_step(&local, &batch, lr)?;
                        local = next;
                        loss
                    }
                };
                loss_sum += loss as f64;
                batches += 1;
            }
            if batches > 0 {
                last_loss = (loss_sum / batches as f64) as f32;
            }
        }
        Ok((local, last_loss))
    }

    /// One eval minibatch: (correct, loss_sum, mask_sum).
    fn eval_batch_raw(&self, params: &ParamSet, batch: &Batch) -> Result<(f64, f64, f64)> {
        match &self.backend {
            Backend::Native(exec) => Ok(exec.eval_batch(params, batch)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.eval_batch(&self.manifest, params, batch),
        }
    }

    /// Streamed evaluation over a whole dataset. On the native backend
    /// one [`native::Scratch`] is recycled across all eval batches.
    pub fn evaluate(&self, params: &ParamSet, data: &Dataset) -> Result<EvalResult> {
        let idx: Vec<u32> = (0..data.len() as u32).collect();
        let mut correct = 0.0;
        let mut loss = 0.0;
        let mut n = 0.0;
        let mut scratch = native::Scratch::new();
        for batch in Minibatches::new(data, &idx, self.manifest.eval_batch) {
            let (c, l, m) = match &self.backend {
                Backend::Native(exec) => exec.eval_batch_with(&mut scratch, params, &batch),
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(_) => self.eval_batch_raw(params, &batch)?,
            };
            correct += c;
            loss += l;
            n += m;
        }
        ensure!(n > 0.0, "empty evaluation set");
        Ok(EvalResult {
            accuracy: correct / n,
            mean_loss: loss / n,
            samples: n as u64,
        })
    }

    /// [`Self::evaluate`] with the eval minibatches fanned out across a
    /// [`ThreadPool`]. Per-batch results are reduced in batch order, so
    /// the outcome is **bit-identical** to the serial path for any
    /// thread count (the pool's core contract).
    pub fn evaluate_pooled(
        &self,
        pool: &ThreadPool,
        params: &ParamSet,
        data: &Dataset,
    ) -> Result<EvalResult> {
        if pool.threads() <= 1 {
            return self.evaluate(params, data);
        }
        let idx: Vec<u32> = (0..data.len() as u32).collect();
        let batches: Vec<Batch> =
            Minibatches::new(data, &idx, self.manifest.eval_batch).collect();
        let parts = pool.try_map(batches.len(), |i| self.eval_batch_raw(params, &batches[i]))?;
        let (mut correct, mut loss, mut n) = (0.0, 0.0, 0.0);
        for (c, l, m) in parts {
            correct += c;
            loss += l;
            n += m;
        }
        ensure!(n > 0.0, "empty evaluation set");
        Ok(EvalResult {
            accuracy: correct / n,
            mean_loss: loss / n,
            samples: n as u64,
        })
    }
}

/// The compiled-HLO PJRT backend (original execution path).
#[cfg(feature = "pjrt")]
struct PjrtBackend {
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
fn literal_from_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    ensure!(n == data.len(), "literal data {} != shape {:?}", data.len(), shape);
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .context("reshaping literal")
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Load and compile both entry points from the artifact dir.
    fn load(dir: &Path, manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("utf-8 path"),
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        };
        let train_exe = load(&manifest.entries.train_step.file)?;
        let eval_exe = load(&manifest.entries.eval_step.file)?;
        Ok(Self { client, train_exe, eval_exe })
    }

    fn param_literals(&self, manifest: &Manifest, params: &ParamSet) -> Result<Vec<xla::Literal>> {
        let shapes = manifest.param_shapes();
        ensure!(params.len() == shapes.len(), "param tensor count mismatch");
        params
            .iter()
            .zip(&shapes)
            .map(|(p, s)| literal_from_f32(p, s))
            .collect()
    }

    /// Upload host literals as *self-owned* device buffers and run the
    /// executable via `execute_b`.
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal-taking variant): xla 0.1.6's C shim leaks every input
    /// buffer it creates there (`BufferFromHostLiteral(..).release()`
    /// with no reclaim — ~2 MB per train step, hundreds of MB/s in the
    /// training loop). With `execute_b` the inputs are `PjRtBuffer`s we
    /// own, freed on drop. See EXPERIMENTS.md §Perf.
    fn run_buffered(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|lit| self.client.buffer_from_host_literal(None, lit))
            .collect::<std::result::Result<_, _>>()
            .context("uploading input buffers")?;
        let out = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .context("executing artifact")?;
        ensure!(
            out.len() == 1 && out[0].len() == 1,
            "expected a single replica with a single tuple output"
        );
        Ok(out[0][0].to_literal_sync()?)
    }

    fn train_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> Result<(ParamSet, f32)> {
        let b = manifest.train_batch;
        let f = manifest.num_features();
        let c = manifest.num_classes();
        let mut inputs = self.param_literals(manifest, params)?;
        inputs.push(literal_from_f32(&batch.x, &[b, f])?);
        inputs.push(literal_from_f32(&batch.y_onehot, &[b, c])?);
        inputs.push(literal_from_f32(&batch.mask, &[b])?);
        inputs.push(xla::Literal::scalar(lr));

        let result = self
            .run_buffered(&self.train_exe, &inputs)
            .context("executing train_step")?;
        let outs = result.to_tuple().context("unpacking train_step tuple")?;
        ensure!(
            outs.len() == manifest.num_param_tensors + 1,
            "train_step returned {} outputs",
            outs.len()
        );
        let mut new_params: ParamSet = Vec::with_capacity(manifest.num_param_tensors);
        for lit in &outs[..manifest.num_param_tensors] {
            new_params.push(lit.to_vec::<f32>()?);
        }
        let loss = outs[manifest.num_param_tensors].to_vec::<f32>()?[0];
        Ok((new_params, loss))
    }

    fn eval_batch(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &Batch,
    ) -> Result<(f64, f64, f64)> {
        let b = manifest.eval_batch;
        let f = manifest.num_features();
        let c = manifest.num_classes();
        let mut inputs = self.param_literals(manifest, params)?;
        inputs.push(literal_from_f32(&batch.x, &[b, f])?);
        inputs.push(literal_from_f32(&batch.y_onehot, &[b, c])?);
        inputs.push(literal_from_f32(&batch.mask, &[b])?);
        let result = self
            .run_buffered(&self.eval_exe, &inputs)
            .context("executing eval_step")?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 3, "eval_step returned {} outputs", outs.len());
        Ok((
            outs[0].to_vec::<f32>()?[0] as f64,
            outs[1].to_vec::<f32>()?[0] as f64,
            outs[2].to_vec::<f32>()?[0] as f64,
        ))
    }
}

/// Default artifact directory: `$ASYNCMEL_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("ASYNCMEL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

// NOTE: tests that need the compiled PJRT artifacts live in
// rust/tests/e2e_runtime.rs (they require `make artifacts` first and
// skip loudly otherwise); the native backend's numerics are unit-tested
// in [`native`].
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("ASYNCMEL_ARTIFACTS", "/tmp/zzz");
        assert_eq!(default_artifacts_dir(), PathBuf::from("/tmp/zzz"));
        std::env::remove_var("ASYNCMEL_ARTIFACTS");
        assert_eq!(default_artifacts_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn native_runtime_round_trips_init_and_eval() {
        let rt = Runtime::native(&[36, 16, 4], 32, 64);
        assert_eq!(rt.platform(), "native-cpu");
        rt.manifest.check().unwrap();
        let mut rng = Rng::new(3);
        let params = rt.init_params(&mut rng);
        let shapes = rt.manifest.param_shapes();
        assert_eq!(params.len(), shapes.len());
        for (p, s) in params.iter().zip(&shapes) {
            assert_eq!(p.len(), s.iter().product::<usize>());
        }
        // biases zero, weights non-degenerate
        assert!(params[1].iter().all(|&v| v == 0.0));
        assert!(params[0].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn pooled_evaluate_is_bit_identical_to_serial() {
        use crate::data::{synth, SynthConfig};
        let rt = Runtime::native(&[36, 16, 4], 32, 48);
        let ds = synth::generate(&SynthConfig {
            side: 6,
            classes: 4,
            train: 64,
            test: 200, // several eval batches incl. a padded tail
            ..SynthConfig::default()
        });
        let mut rng = Rng::new(9);
        let params = rt.init_params(&mut rng);
        let serial = rt.evaluate(&params, &ds.test).unwrap();
        for threads in [2usize, 3, 8] {
            let pooled = rt
                .evaluate_pooled(&ThreadPool::new(threads), &params, &ds.test)
                .unwrap();
            assert_eq!(serial, pooled, "threads={threads}");
        }
    }

    #[test]
    fn load_without_artifacts_errors() {
        let err = Runtime::load("/definitely/not/a/dir").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest.json"), "{msg}");
    }

    #[cfg(feature = "pjrt")]
    mod pjrt_literals {
        use super::super::*;

        #[test]
        fn literal_round_trips_shape() {
            let lit = literal_from_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
            assert_eq!(lit.element_count(), 6);
            assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        }

        #[test]
        fn literal_scalar() {
            let lit = literal_from_f32(&[7.5], &[]).unwrap();
            assert_eq!(lit.to_vec::<f32>().unwrap(), vec![7.5]);
        }

        #[test]
        fn literal_rejects_bad_length() {
            assert!(literal_from_f32(&[1.0, 2.0], &[3]).is_err());
        }
    }
}
