//! Artifact manifest — the shape contract between `python/compile/aot.py`
//! and the rust executor.
//!
//! `aot.py` writes `artifacts/manifest.json` alongside the HLO text; the
//! runtime refuses to execute artifacts whose manifest disagrees with
//! what the coordinator is about to feed them (wrong batch size, wrong
//! parameter count, …) — shape bugs surface at load time, not as NaNs.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::json::Value;

/// Shape+dtype of one input tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT entry point (train_step / eval_step).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub num_outputs: usize,
}

/// The manifest as written by `compile.aot.build_manifest`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub layer_dims: Vec<usize>,
    pub num_param_tensors: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub model_size_bits: u64,
    pub entries: Entries,
}

#[derive(Debug, Clone)]
pub struct Entries {
    pub train_step: EntrySpec,
    pub eval_step: EntrySpec,
}

impl Manifest {
    /// Load and sanity-check `manifest.json` from the artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = crate::json::parse(&text).context("parsing artifact manifest")?;
        let m = Self::from_json(&v).context("decoding artifact manifest")?;
        m.check()?;
        Ok(m)
    }

    /// Decode from a JSON value (shape written by `compile.aot`).
    pub fn from_json(v: &Value) -> Result<Self> {
        let tensor = |t: &Value| -> Result<TensorSpec> {
            let shape = t
                .field("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { shape, dtype: t.str_field("dtype")?.to_string() })
        };
        let entry = |e: &Value| -> Result<EntrySpec> {
            Ok(EntrySpec {
                file: e.str_field("file")?.to_string(),
                inputs: e
                    .field("inputs")?
                    .as_arr()?
                    .iter()
                    .map(tensor)
                    .collect::<Result<Vec<_>>>()?,
                num_outputs: e.usize_field("num_outputs")?,
            })
        };
        let entries = v.field("entries")?;
        Ok(Manifest {
            layer_dims: v
                .field("layer_dims")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            num_param_tensors: v.usize_field("num_param_tensors")?,
            train_batch: v.usize_field("train_batch")?,
            eval_batch: v.usize_field("eval_batch")?,
            model_size_bits: v.u64_field("model_size_bits")?,
            entries: Entries {
                train_step: entry(entries.field("train_step")?)?,
                eval_step: entry(entries.field("eval_step")?)?,
            },
        })
    }

    /// Synthesize a manifest for the in-process native executor — no
    /// artifact files involved. Shapes follow the same `[w, b] × layers`
    /// convention `compile.aot` writes, so [`Manifest::check`] holds by
    /// construction.
    pub fn native(layer_dims: &[usize], train_batch: usize, eval_batch: usize) -> Manifest {
        assert!(layer_dims.len() >= 2, "model needs >= 2 layer dims");
        assert!(train_batch > 0 && eval_batch > 0);
        let f32s = |shape: Vec<usize>| TensorSpec { shape, dtype: "float32".to_string() };
        let mut params = Vec::new();
        // The paper's S_m counts the weight matrices only (§V-A quotes
        // 8,974,080 bits = 280,440 f32 weights; biases excluded) — match
        // the convention `compile.model.model_size_bits` uses.
        let mut n_weights = 0usize;
        for l in 0..layer_dims.len() - 1 {
            params.push(f32s(vec![layer_dims[l], layer_dims[l + 1]]));
            params.push(f32s(vec![layer_dims[l + 1]]));
            n_weights += layer_dims[l] * layer_dims[l + 1];
        }
        let num_param_tensors = params.len();
        let features = layer_dims[0];
        let classes = *layer_dims.last().unwrap();
        let batch_inputs = |b: usize| {
            vec![
                f32s(vec![b, features]),
                f32s(vec![b, classes]),
                f32s(vec![b]),
            ]
        };
        let mut train_inputs = params.clone();
        train_inputs.extend(batch_inputs(train_batch));
        train_inputs.push(f32s(vec![])); // lr scalar
        let mut eval_inputs = params;
        eval_inputs.extend(batch_inputs(eval_batch));
        Manifest {
            layer_dims: layer_dims.to_vec(),
            num_param_tensors,
            train_batch,
            eval_batch,
            model_size_bits: 32 * n_weights as u64,
            entries: Entries {
                train_step: EntrySpec {
                    file: "<native>".to_string(),
                    inputs: train_inputs,
                    num_outputs: num_param_tensors + 1,
                },
                eval_step: EntrySpec {
                    file: "<native>".to_string(),
                    inputs: eval_inputs,
                    num_outputs: 3,
                },
            },
        }
    }

    /// Internal consistency checks.
    pub fn check(&self) -> Result<()> {
        ensure!(self.layer_dims.len() >= 2, "model needs >= 2 layer dims");
        ensure!(
            self.num_param_tensors == 2 * (self.layer_dims.len() - 1),
            "param tensor count {} != 2 x layers",
            self.num_param_tensors
        );
        let t = &self.entries.train_step;
        ensure!(
            t.inputs.len() == self.num_param_tensors + 4,
            "train_step arity {}",
            t.inputs.len()
        );
        ensure!(t.num_outputs == self.num_param_tensors + 1);
        let e = &self.entries.eval_step;
        ensure!(e.inputs.len() == self.num_param_tensors + 3);
        ensure!(e.num_outputs == 3);
        // parameter shapes must follow the [w, b] x layers convention
        for l in 0..self.layer_dims.len() - 1 {
            let w = &t.inputs[2 * l];
            let b = &t.inputs[2 * l + 1];
            ensure!(
                w.shape == vec![self.layer_dims[l], self.layer_dims[l + 1]],
                "w{l} shape {:?}",
                w.shape
            );
            ensure!(b.shape == vec![self.layer_dims[l + 1]], "b{l} shape {:?}", b.shape);
        }
        // batch rows
        let x = &t.inputs[self.num_param_tensors];
        ensure!(
            x.shape == vec![self.train_batch, self.layer_dims[0]],
            "train x shape {:?}",
            x.shape
        );
        let xe = &e.inputs[self.num_param_tensors];
        ensure!(xe.shape == vec![self.eval_batch, self.layer_dims[0]]);
        Ok(())
    }

    /// Flat parameter-tensor shapes `[w1, b1, …]`.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        self.entries.train_step.inputs[..self.num_param_tensors]
            .iter()
            .map(|t| t.shape.clone())
            .collect()
    }

    pub fn num_features(&self) -> usize {
        self.layer_dims[0]
    }

    pub fn num_classes(&self) -> usize {
        *self.layer_dims.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let dims = [784usize, 300, 124, 60, 10];
        let mut inputs = Vec::new();
        for l in 0..4 {
            inputs.push(TensorSpec { shape: vec![dims[l], dims[l + 1]], dtype: "float32".into() });
            inputs.push(TensorSpec { shape: vec![dims[l + 1]], dtype: "float32".into() });
        }
        let mut train_inputs = inputs.clone();
        train_inputs.push(TensorSpec { shape: vec![128, 784], dtype: "float32".into() });
        train_inputs.push(TensorSpec { shape: vec![128, 10], dtype: "float32".into() });
        train_inputs.push(TensorSpec { shape: vec![128], dtype: "float32".into() });
        train_inputs.push(TensorSpec { shape: vec![], dtype: "float32".into() });
        let mut eval_inputs = inputs;
        eval_inputs.push(TensorSpec { shape: vec![512, 784], dtype: "float32".into() });
        eval_inputs.push(TensorSpec { shape: vec![512, 10], dtype: "float32".into() });
        eval_inputs.push(TensorSpec { shape: vec![512], dtype: "float32".into() });
        Manifest {
            layer_dims: dims.to_vec(),
            num_param_tensors: 8,
            train_batch: 128,
            eval_batch: 512,
            model_size_bits: 8_974_080,
            entries: Entries {
                train_step: EntrySpec {
                    file: "train_step.hlo.txt".into(),
                    inputs: train_inputs,
                    num_outputs: 9,
                },
                eval_step: EntrySpec {
                    file: "eval_step.hlo.txt".into(),
                    inputs: eval_inputs,
                    num_outputs: 3,
                },
            },
        }
    }

    #[test]
    fn valid_manifest_checks_out() {
        sample().check().unwrap();
        assert_eq!(sample().num_features(), 784);
        assert_eq!(sample().num_classes(), 10);
        assert_eq!(sample().param_shapes().len(), 8);
    }

    #[test]
    fn wrong_batch_rejected() {
        let mut m = sample();
        m.train_batch = 64;
        assert!(m.check().is_err());
    }

    #[test]
    fn wrong_param_count_rejected() {
        let mut m = sample();
        m.num_param_tensors = 6;
        assert!(m.check().is_err());
    }

    #[test]
    fn native_manifest_checks_out() {
        let m = Manifest::native(&[784, 300, 124, 60, 10], 128, 512);
        m.check().unwrap();
        assert_eq!(m.num_param_tensors, 8);
        assert_eq!(m.model_size_bits, 8_974_080);
        assert_eq!(m.num_features(), 784);
        assert_eq!(m.num_classes(), 10);
        let tiny = Manifest::native(&[36, 16, 4], 32, 64);
        tiny.check().unwrap();
        assert_eq!(tiny.param_shapes(), vec![vec![36, 16], vec![16], vec![16, 4], vec![4]]);
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { shape: vec![3, 4], dtype: "float32".into() };
        assert_eq!(t.num_elements(), 12);
        let s = TensorSpec { shape: vec![], dtype: "float32".into() };
        assert_eq!(s.num_elements(), 1);
    }
}
