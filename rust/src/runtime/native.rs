//! Pure-Rust executor for the paper's ReLU-MLP — the hermetic default
//! backend of [`crate::runtime::Runtime`].
//!
//! Implements exactly the two entry points the AOT artifacts expose
//! (`train_step`, `eval_step`) for an arbitrary `layer_dims` stack:
//! dense → ReLU hidden layers, softmax cross-entropy on the logits,
//! masked padded rows, plain SGD. The offline registry cannot always
//! provide the `xla` crate chain, so this backend keeps
//! `cargo build && cargo test` self-contained; the `pjrt` feature swaps
//! in the compiled-HLO path with identical semantics.

use crate::aggregation::ParamSet;
use crate::data::Batch;

/// In-process MLP forward/backward engine.
#[derive(Debug, Clone)]
pub struct NativeExecutor {
    /// `[features, hidden…, classes]`.
    pub dims: Vec<usize>,
}

/// `x[rows, in] @ w[in, out] + b[out]`.
fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], rows: usize, in_d: usize, out_d: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * in_d);
    debug_assert_eq!(w.len(), in_d * out_d);
    debug_assert_eq!(b.len(), out_d);
    let mut out = vec![0.0f32; rows * out_d];
    for r in 0..rows {
        let xr = &x[r * in_d..(r + 1) * in_d];
        let or = &mut out[r * out_d..(r + 1) * out_d];
        or.copy_from_slice(b);
        for (i, &xi) in xr.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let wrow = &w[i * out_d..(i + 1) * out_d];
            for (o, &wij) in or.iter_mut().zip(wrow) {
                *o += xi * wij;
            }
        }
    }
    out
}

impl NativeExecutor {
    pub fn new(dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "need at least input and output dims");
        Self { dims: dims.to_vec() }
    }

    fn layers(&self) -> usize {
        self.dims.len() - 1
    }

    fn check_params(&self, params: &ParamSet) {
        assert_eq!(params.len(), 2 * self.layers(), "param tensor count");
        for l in 0..self.layers() {
            assert_eq!(params[2 * l].len(), self.dims[l] * self.dims[l + 1], "w{l} size");
            assert_eq!(params[2 * l + 1].len(), self.dims[l + 1], "b{l} size");
        }
    }

    /// Forward pass keeping every activation (`acts[0]` = input,
    /// `acts[L]` = logits; hidden activations are post-ReLU).
    fn forward(&self, params: &ParamSet, x: &[f32], rows: usize) -> Vec<Vec<f32>> {
        let l_count = self.layers();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(l_count + 1);
        acts.push(x.to_vec());
        for l in 0..l_count {
            let mut z = matmul_bias(
                &acts[l],
                &params[2 * l],
                &params[2 * l + 1],
                rows,
                self.dims[l],
                self.dims[l + 1],
            );
            if l + 1 < l_count {
                for v in &mut z {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(z);
        }
        acts
    }

    /// Per-row softmax cross-entropy: fills `probs` (softmax of the row)
    /// and returns the loss `-ln p[label]`.
    fn row_loss(logits: &[f32], label: usize, probs: &mut [f32]) -> f32 {
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (p, &z) in probs.iter_mut().zip(logits) {
            *p = (z - m).exp();
            sum += *p;
        }
        for p in probs.iter_mut() {
            *p /= sum;
        }
        sum.ln() + m - logits[label]
    }

    /// One SGD minibatch step; mirrors the AOT `train_step` contract:
    /// returns the updated parameters and the masked mean loss.
    pub fn train_step(&self, params: &ParamSet, batch: &Batch, lr: f32) -> (ParamSet, f32) {
        self.check_params(params);
        let rows = batch.mask.len();
        let c = *self.dims.last().unwrap();
        assert_eq!(batch.x.len(), rows * self.dims[0], "batch x shape");
        assert_eq!(batch.y_onehot.len(), rows * c, "batch y shape");

        let l_count = self.layers();
        let acts = self.forward(params, &batch.x, rows);
        let logits = &acts[l_count];

        let mask_sum: f32 = batch.mask.iter().sum();
        debug_assert!(mask_sum > 0.0, "all-padded batch");
        let inv = 1.0 / mask_sum;

        // dL/dlogits = (softmax − y) / Σmask on real rows, 0 on padding.
        let mut delta = vec![0.0f32; rows * c];
        let mut probs = vec![0.0f32; c];
        let mut loss = 0.0f64;
        for r in 0..rows {
            if batch.mask[r] == 0.0 {
                continue;
            }
            let yr = &batch.y_onehot[r * c..(r + 1) * c];
            let label = yr
                .iter()
                .position(|&v| v == 1.0)
                .expect("one-hot row without a label");
            loss += Self::row_loss(&logits[r * c..(r + 1) * c], label, &mut probs) as f64;
            let dr = &mut delta[r * c..(r + 1) * c];
            for j in 0..c {
                dr[j] = (probs[j] - yr[j]) * inv;
            }
        }
        let loss = (loss * inv as f64) as f32;

        // Backward + SGD, layer by layer from the top.
        let mut new_params = params.clone();
        for l in (0..l_count).rev() {
            let (in_d, out_d) = (self.dims[l], self.dims[l + 1]);
            let a_in = &acts[l];
            let w = &params[2 * l];

            // gw = a_inᵀ @ delta, gb = Σ_rows delta
            let mut gw = vec![0.0f32; in_d * out_d];
            let mut gb = vec![0.0f32; out_d];
            for r in 0..rows {
                let dr = &delta[r * out_d..(r + 1) * out_d];
                let ar = &a_in[r * in_d..(r + 1) * in_d];
                for (g, &d) in gb.iter_mut().zip(dr) {
                    *g += d;
                }
                for (i, &ai) in ar.iter().enumerate() {
                    if ai == 0.0 {
                        continue;
                    }
                    let grow = &mut gw[i * out_d..(i + 1) * out_d];
                    for (g, &d) in grow.iter_mut().zip(dr) {
                        *g += ai * d;
                    }
                }
            }

            // delta ← (delta @ wᵀ) ⊙ relu'(a_in) for the layer below
            if l > 0 {
                let mut prev = vec![0.0f32; rows * in_d];
                for r in 0..rows {
                    let dr = &delta[r * out_d..(r + 1) * out_d];
                    let ar = &a_in[r * in_d..(r + 1) * in_d];
                    let pr = &mut prev[r * in_d..(r + 1) * in_d];
                    for i in 0..in_d {
                        if ar[i] <= 0.0 {
                            continue; // ReLU gate closed
                        }
                        let wrow = &w[i * out_d..(i + 1) * out_d];
                        let mut s = 0.0f32;
                        for (wj, &dj) in wrow.iter().zip(dr) {
                            s += wj * dj;
                        }
                        pr[i] = s;
                    }
                }
                delta = prev;
            }

            for (p, &g) in new_params[2 * l].iter_mut().zip(&gw) {
                *p -= lr * g;
            }
            for (p, &g) in new_params[2 * l + 1].iter_mut().zip(&gb) {
                *p -= lr * g;
            }
        }
        (new_params, loss)
    }

    /// One eval minibatch; mirrors the AOT `eval_step` contract:
    /// `(correct, loss_sum, mask_sum)` over the real rows.
    pub fn eval_batch(&self, params: &ParamSet, batch: &Batch) -> (f64, f64, f64) {
        self.check_params(params);
        let rows = batch.mask.len();
        let c = *self.dims.last().unwrap();
        let acts = self.forward(params, &batch.x, rows);
        let logits = &acts[self.layers()];
        let mut probs = vec![0.0f32; c];
        let (mut correct, mut loss_sum, mut mask_sum) = (0.0f64, 0.0f64, 0.0f64);
        for r in 0..rows {
            if batch.mask[r] == 0.0 {
                continue;
            }
            let yr = &batch.y_onehot[r * c..(r + 1) * c];
            let label = yr
                .iter()
                .position(|&v| v == 1.0)
                .expect("one-hot row without a label");
            let zr = &logits[r * c..(r + 1) * c];
            loss_sum += Self::row_loss(zr, label, &mut probs) as f64;
            let pred = zr
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            if pred == label {
                correct += 1.0;
            }
            mask_sum += 1.0;
        }
        (correct, loss_sum, mask_sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Minibatches, SynthConfig};
    use crate::sim::Rng;

    fn tiny_dims() -> Vec<usize> {
        vec![36, 16, 4]
    }

    fn he_params(dims: &[usize], rng: &mut Rng) -> ParamSet {
        let mut out = Vec::new();
        for l in 0..dims.len() - 1 {
            let std = (2.0 / dims[l] as f64).sqrt();
            out.push(
                (0..dims[l] * dims[l + 1])
                    .map(|_| rng.normal_ms(0.0, std) as f32)
                    .collect(),
            );
            out.push(vec![0.0f32; dims[l + 1]]);
        }
        out
    }

    fn tiny_data() -> crate::data::SynthDataset {
        synth::generate(&SynthConfig {
            side: 6,
            classes: 4,
            train: 128,
            test: 64,
            noise_std: 0.4,
            ..SynthConfig::default()
        })
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(11);
        let mut params = he_params(&dims, &mut rng);
        let idx: Vec<u32> = (0..64).collect();
        let batch = Minibatches::new(&ds.train, &idx, 64).next().unwrap();
        let (_, loss0) = exec.train_step(&params, &batch, 0.2);
        let mut last = loss0;
        for _ in 0..30 {
            let (next, loss) = exec.train_step(&params, &batch, 0.2);
            params = next;
            last = loss;
        }
        assert!(last < loss0 * 0.7, "loss did not drop: {loss0} -> {last}");
        for t in &params {
            assert!(t.iter().all(|v| v.is_finite()), "NaN/Inf in params");
        }
    }

    #[test]
    fn untrained_eval_is_chance_level_and_counts_mask() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(5);
        let params = he_params(&dims, &mut rng);
        let idx: Vec<u32> = (0..64).collect();
        let mut correct = 0.0;
        let mut n = 0.0;
        for batch in Minibatches::new(&ds.test, &idx, 48) {
            let (c, l, m) = exec.eval_batch(&params, &batch);
            assert!(l.is_finite() && l > 0.0);
            correct += c;
            n += m;
        }
        assert_eq!(n, 64.0, "mask sum must count only real rows");
        let acc = correct / n;
        assert!((0.0..0.8).contains(&acc), "untrained accuracy {acc}");
    }

    #[test]
    fn padded_rows_do_not_contribute_gradient() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(7);
        let params = he_params(&dims, &mut rng);
        // 10 real rows padded to 32 vs exactly 10 rows: identical update
        let idx: Vec<u32> = (0..10).collect();
        let padded = Minibatches::new(&ds.train, &idx, 32).next().unwrap();
        let tight = Minibatches::new(&ds.train, &idx, 10).next().unwrap();
        let (p_pad, l_pad) = exec.train_step(&params, &padded, 0.1);
        let (p_tight, l_tight) = exec.train_step(&params, &tight, 0.1);
        assert_eq!(l_pad, l_tight);
        for (a, b) in p_pad.iter().zip(&p_tight) {
            assert_eq!(a, b, "padding changed the update");
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // spot-check dL/dw on a few coordinates via central differences
        let dims = vec![6, 5, 3];
        let exec = NativeExecutor::new(&dims);
        let mut rng = Rng::new(3);
        let params = he_params(&dims, &mut rng);
        let rows = 4usize;
        let x: Vec<f32> = (0..rows * 6).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; rows * 3];
        for r in 0..rows {
            y[r * 3 + r % 3] = 1.0;
        }
        let batch = Batch { x, y_onehot: y, mask: vec![1.0; rows], real: rows };

        let loss_at = |p: &ParamSet| -> f64 {
            let (_, loss_sum, mask_sum) = exec.eval_batch(p, &batch);
            loss_sum / mask_sum
        };
        let lr = 1.0f32; // step == gradient, so (params - new) = grad
        let (stepped, _) = exec.train_step(&params, &batch, lr);
        let eps = 1e-3f32;
        for (ti, vi) in [(0usize, 1usize), (1, 2), (2, 4), (3, 0)] {
            let analytic = params[ti][vi] - stepped[ti][vi];
            let mut plus = params.clone();
            plus[ti][vi] += eps;
            let mut minus = params.clone();
            minus[ti][vi] -= eps;
            let numeric = ((loss_at(&plus) - loss_at(&minus)) / (2.0 * eps as f64)) as f32;
            assert!(
                (analytic - numeric).abs() < 2e-2_f32.max(0.2 * numeric.abs()),
                "tensor {ti}[{vi}]: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn training_learns_separable_clusters() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(19);
        let mut params = he_params(&dims, &mut rng);
        let idx: Vec<u32> = (0..ds.train.len() as u32).collect();
        for _epoch in 0..20 {
            for batch in Minibatches::new(&ds.train, &idx, 32) {
                let (next, _) = exec.train_step(&params, &batch, 0.2);
                params = next;
            }
        }
        let test_idx: Vec<u32> = (0..ds.test.len() as u32).collect();
        let (mut correct, mut n) = (0.0, 0.0);
        for batch in Minibatches::new(&ds.test, &test_idx, 32) {
            let (c, _, m) = exec.eval_batch(&params, &batch);
            correct += c;
            n += m;
        }
        let acc = correct / n;
        assert!(acc > 0.6, "trained accuracy {acc} (chance 0.25)");
    }
}
