//! Pure-Rust executor for the paper's ReLU-MLP — the hermetic default
//! backend of [`crate::runtime::Runtime`].
//!
//! Implements exactly the two entry points the AOT artifacts expose
//! (`train_step`, `eval_step`) for an arbitrary `layer_dims` stack:
//! dense → ReLU hidden layers, softmax cross-entropy on the logits,
//! masked padded rows, plain SGD. The offline registry cannot always
//! provide the `xla` crate chain, so this backend keeps
//! `cargo build && cargo test` self-contained; the `pjrt` feature swaps
//! in the compiled-HLO path with identical semantics.
//!
//! ## The zero-alloc hot path
//!
//! The original implementation allocated on every step: a clone of the
//! input batch into `acts[0]`, a fresh `Vec` per activation, per-layer
//! gradient buffers, `delta`/`probs`, and a full parameter clone. With
//! fleets of learners stepping thousands of times per run those
//! allocations dominated the (small-matrix) math, so the hot path now
//! runs through a reusable [`Scratch`]:
//!
//! * the input batch is **borrowed**, never copied — `acts` holds only
//!   the layer *outputs*;
//! * all intermediate buffers live in the `Scratch` and are recycled
//!   across steps (`clear` + `resize` keeps capacity, so after the
//!   first step nothing allocates);
//! * [`NativeExecutor::train_step_into`] updates the parameters **in
//!   place** (gradients for a layer are fully consumed before that
//!   layer's weights are touched, so the result is bit-identical to
//!   the old clone-then-update flow);
//! * the forward matmul is register-blocked over the output dimension
//!   ([`TILE`]-wide accumulator tiles that stay in registers across
//!   the whole input-dim loop), and the backward delta pass runs on a
//!   **cached transposed-weight layout** (`wT`), turning an
//!   unvectorizable dot-reduction into per-row axpy sweeps.
//!
//! Every optimization preserves the original *per-output-element
//! accumulation order* (ascending input index forward, ascending
//! output index backward, ascending row for gradients, identical
//! zero-skip conditions), so results are **bit-identical** to the
//! previous backend — asserted against a kept reference implementation
//! in the tests below and by the repo's golden digests.

use anyhow::{ensure, Result};

use crate::aggregation::ParamSet;
use crate::data::{Batch, Dataset};
use crate::runtime::{TrainTask, TrainOutcome};

/// Native f32 SIMD width the batched kernels are tiled around (one
/// 256-bit AVX2 register = 8 f32 lanes; [`TILE`] is two such lanes).
/// Exported so the batched-vs-per-learner differential tests can probe
/// the ragged edges (`W − 1`, `W`, `W + 1`).
pub const SIMD_WIDTH: usize = 8;

/// Output-dimension register tile for the forward matmul: small enough
/// to stay in vector registers, wide enough to keep SIMD lanes full.
const TILE: usize = 2 * SIMD_WIDTH;

/// Row-block width of the batched kernels: one weight-row load is
/// reused across this many batch rows (the registers hold a
/// `ROW_BLOCK × TILE` accumulator panel).
const ROW_BLOCK: usize = 4;

/// Reusable per-learner working memory for the executor's hot path.
/// One `Scratch` serves any (batch, layer-stack) shape — buffers grow
/// to the high-water mark and are recycled; after the first step a
/// train/eval call performs **no heap allocation**.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    /// Per-layer outputs: `acts[l]` is layer `l`'s output (post-ReLU
    /// for hidden layers, raw logits at the top). The input batch is
    /// borrowed by the forward pass, never stored.
    acts: Vec<Vec<f32>>,
    /// dL/dz of the layer currently being backpropagated.
    delta: Vec<f32>,
    /// dL/dz of the layer below (swapped with `delta` per layer).
    prev: Vec<f32>,
    /// Per-row softmax buffer.
    probs: Vec<f32>,
    /// Weight/bias gradients of the layer being backpropagated.
    gw: Vec<f32>,
    gb: Vec<f32>,
    /// Cached transposed weights `wT[o·in + i] = w[i·out + o]` for the
    /// backward delta pass (rebuilt once per layer per step, reused
    /// across every row of the batch).
    wt: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Reset `buf` to `n` zeros without giving up its capacity.
#[inline]
fn zeroed(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// In-process MLP forward/backward engine.
#[derive(Debug, Clone)]
pub struct NativeExecutor {
    /// `[features, hidden…, classes]`.
    pub dims: Vec<usize>,
}

/// `out[rows, out_d] = x[rows, in_d] @ w[in_d, out_d] + b[out_d]`,
/// written into a caller-provided buffer.
///
/// Register-blocked over the output dimension: a `TILE`-wide
/// accumulator tile is loaded from the bias once, kept live across the
/// whole input loop, and stored once. Per output element the
/// accumulation order is ascending `i` with the exact `xi == 0` skip of
/// the scalar loop — bit-identical results, far fewer memory round
/// trips.
fn matmul_bias_into(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    b: &[f32],
    rows: usize,
    in_d: usize,
    out_d: usize,
) {
    debug_assert_eq!(x.len(), rows * in_d);
    debug_assert_eq!(w.len(), in_d * out_d);
    debug_assert_eq!(b.len(), out_d);
    debug_assert_eq!(out.len(), rows * out_d);
    for r in 0..rows {
        let xr = &x[r * in_d..(r + 1) * in_d];
        let or = &mut out[r * out_d..(r + 1) * out_d];
        let mut o0 = 0;
        while o0 < out_d {
            let ow = TILE.min(out_d - o0);
            let mut acc = [0.0f32; TILE];
            acc[..ow].copy_from_slice(&b[o0..o0 + ow]);
            for (i, &xi) in xr.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let wrow = &w[i * out_d + o0..i * out_d + o0 + ow];
                for (a, &wij) in acc[..ow].iter_mut().zip(wrow) {
                    *a += xi * wij;
                }
            }
            or[o0..o0 + ow].copy_from_slice(&acc[..ow]);
            o0 += ow;
        }
    }
}

/// `acc[..] += scale * row[..]` with the hot loop's exact `scale == 0`
/// skip — the per-element accumulation the whole backend is built from.
/// Under `fast-numerics` the skip is dropped and the multiply-add fuses
/// (FMA): branchless and faster, but differently rounded, so the
/// feature trades bit-equality for the tolerance-differential contract.
#[inline(always)]
fn lanes_axpy(acc: &mut [f32], scale: f32, row: &[f32]) {
    #[cfg(not(feature = "fast-numerics"))]
    {
        if scale == 0.0 {
            return;
        }
        for (a, &v) in acc.iter_mut().zip(row) {
            *a += scale * v;
        }
    }
    #[cfg(feature = "fast-numerics")]
    for (a, &v) in acc.iter_mut().zip(row) {
        *a = scale.mul_add(v, *a);
    }
}

/// Row-blocked variant of [`matmul_bias_into`] for the batched path:
/// a `ROW_BLOCK × TILE` accumulator panel keeps each weight-row load
/// live across `ROW_BLOCK` batch rows instead of one. Per output
/// element the accumulation is still bias-first then ascending `i` with
/// the same `xi == 0` skip, so the default build is bit-identical to
/// the scalar-row kernel (asserted in the tests below); `fast-numerics`
/// swaps the inner step for fused multiply-adds via [`lanes_axpy`].
fn matmul_bias_rows(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    b: &[f32],
    rows: usize,
    in_d: usize,
    out_d: usize,
) {
    debug_assert_eq!(x.len(), rows * in_d);
    debug_assert_eq!(w.len(), in_d * out_d);
    debug_assert_eq!(b.len(), out_d);
    debug_assert_eq!(out.len(), rows * out_d);
    let mut r0 = 0;
    while r0 < rows {
        let rb = ROW_BLOCK.min(rows - r0);
        let mut o0 = 0;
        while o0 < out_d {
            let ow = TILE.min(out_d - o0);
            let mut acc = [[0.0f32; TILE]; ROW_BLOCK];
            for a in acc.iter_mut().take(rb) {
                a[..ow].copy_from_slice(&b[o0..o0 + ow]);
            }
            for i in 0..in_d {
                let wrow = &w[i * out_d + o0..i * out_d + o0 + ow];
                for (rr, a) in acc.iter_mut().take(rb).enumerate() {
                    lanes_axpy(&mut a[..ow], x[(r0 + rr) * in_d + i], wrow);
                }
            }
            for (rr, a) in acc.iter().take(rb).enumerate() {
                let orow = (r0 + rr) * out_d + o0;
                out[orow..orow + ow].copy_from_slice(&a[..ow]);
            }
            o0 += ow;
        }
        r0 += rb;
    }
}

/// Row-blocked weight-gradient accumulation for the batched path:
/// `gw[i, ·] += Σ_r a[r, i] · delta[r, ·]`. The `gw` tile is loaded
/// once per `ROW_BLOCK` rows instead of read-modified-written per row.
/// Contributions land per element in ascending-`r` order with the hot
/// loop's `ai == 0` skip — bit-identical to the per-learner sweep
/// (under `fast-numerics`, FMA without the skip).
fn grad_weights_rows(
    gw: &mut [f32],
    a_in: &[f32],
    delta: &[f32],
    rows: usize,
    in_d: usize,
    out_d: usize,
) {
    debug_assert_eq!(gw.len(), in_d * out_d);
    debug_assert_eq!(a_in.len(), rows * in_d);
    debug_assert_eq!(delta.len(), rows * out_d);
    let mut r0 = 0;
    while r0 < rows {
        let rb = ROW_BLOCK.min(rows - r0);
        for i in 0..in_d {
            let mut o0 = 0;
            while o0 < out_d {
                let ow = TILE.min(out_d - o0);
                let mut acc = [0.0f32; TILE];
                acc[..ow].copy_from_slice(&gw[i * out_d + o0..i * out_d + o0 + ow]);
                for rr in 0..rb {
                    let dr = &delta[(r0 + rr) * out_d + o0..(r0 + rr) * out_d + o0 + ow];
                    lanes_axpy(&mut acc[..ow], a_in[(r0 + rr) * in_d + i], dr);
                }
                gw[i * out_d + o0..i * out_d + o0 + ow].copy_from_slice(&acc[..ow]);
                o0 += ow;
            }
        }
        r0 += rb;
    }
}

/// Batch-striped working memory for [`NativeExecutor::train_many`]:
/// the PR-5 [`Scratch`] layout extended with a learner-stripe
/// dimension. For a batch of `B` learners each buffer holds `B`
/// contiguous stripes (`stripe b` = learner `b`'s rows), so one warmed
/// `BatchScratch` serves every step of every epoch of every learner in
/// the flush with **no heap allocation** — including the gathered
/// minibatch (`x`/`y`/`mask`), which replaces the per-step `Vec`
/// triple `Minibatches` allocates on the per-learner path.
#[derive(Debug, Clone, Default)]
pub struct BatchScratch {
    /// Per-layer outputs, `B` stripes of `rows × out_d` each.
    acts: Vec<Vec<f32>>,
    /// dL/dz stripes of the layer being backpropagated.
    delta: Vec<f32>,
    /// dL/dz stripes of the layer below (swapped per layer).
    prev: Vec<f32>,
    /// Per-row softmax buffer (rows are processed serially, so one
    /// buffer serves all stripes).
    probs: Vec<f32>,
    /// Gradients + transposed weights of the learner currently being
    /// updated (consumed stripe-by-stripe, so not striped themselves).
    gw: Vec<f32>,
    gb: Vec<f32>,
    wt: Vec<f32>,
    /// Gathered minibatch stripes: learner `b`'s current `rows × f`
    /// inputs, `rows × c` one-hots and `rows` mask.
    x: Vec<f32>,
    y: Vec<f32>,
    mask: Vec<f32>,
    /// Per-learner masked mean loss of the current step.
    step_loss: Vec<f32>,
}

impl BatchScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

impl NativeExecutor {
    pub fn new(dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "need at least input and output dims");
        Self { dims: dims.to_vec() }
    }

    fn layers(&self) -> usize {
        self.dims.len() - 1
    }

    fn check_params(&self, params: &ParamSet) {
        assert_eq!(params.len(), 2 * self.layers(), "param tensor count");
        for l in 0..self.layers() {
            assert_eq!(params[2 * l].len(), self.dims[l] * self.dims[l + 1], "w{l} size");
            assert_eq!(params[2 * l + 1].len(), self.dims[l + 1], "b{l} size");
        }
    }

    /// Forward pass into the scratch (`s.acts[l]` = layer `l`'s output;
    /// hidden activations post-ReLU, top layer raw logits). The input
    /// batch `x` is borrowed — nothing copies it.
    fn forward_scratch(&self, s: &mut Scratch, params: &ParamSet, x: &[f32], rows: usize) {
        let l_count = self.layers();
        while s.acts.len() < l_count {
            s.acts.push(Vec::new());
        }
        for l in 0..l_count {
            let (in_d, out_d) = (self.dims[l], self.dims[l + 1]);
            let (below, rest) = s.acts.split_at_mut(l);
            let input: &[f32] = if l == 0 { x } else { &below[l - 1] };
            let z = &mut rest[0];
            z.resize(rows * out_d, 0.0);
            matmul_bias_into(z, input, &params[2 * l], &params[2 * l + 1], rows, in_d, out_d);
            if l + 1 < l_count {
                for v in z.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
        }
    }

    /// Per-row softmax cross-entropy: fills `probs` (softmax of the row)
    /// and returns the loss `-ln p[label]`.
    fn row_loss(logits: &[f32], label: usize, probs: &mut [f32]) -> f32 {
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (p, &z) in probs.iter_mut().zip(logits) {
            *p = (z - m).exp();
            sum += *p;
        }
        for p in probs.iter_mut() {
            *p /= sum;
        }
        sum.ln() + m - logits[label]
    }

    /// One SGD minibatch step; mirrors the AOT `train_step` contract:
    /// returns the updated parameters and the masked mean loss.
    ///
    /// Convenience wrapper over [`Self::train_step_into`] for callers
    /// without a step loop; the hot path
    /// ([`crate::runtime::Runtime::train_epochs`]) keeps one [`Scratch`]
    /// and a single parameter buffer across all steps instead.
    pub fn train_step(&self, params: &ParamSet, batch: &Batch, lr: f32) -> (ParamSet, f32) {
        let mut scratch = Scratch::new();
        let mut local = params.clone();
        let loss = self.train_step_into(&mut scratch, &mut local, batch, lr);
        (local, loss)
    }

    /// One SGD minibatch step **in place**: `params` is updated
    /// directly and the masked mean loss returned. Allocation-free
    /// after the scratch's first use. Bit-identical to
    /// [`Self::train_step`]: every gradient a layer needs is computed
    /// from the pre-step values before that layer's parameters are
    /// written.
    pub fn train_step_into(
        &self,
        s: &mut Scratch,
        params: &mut ParamSet,
        batch: &Batch,
        lr: f32,
    ) -> f32 {
        self.check_params(params);
        let rows = batch.mask.len();
        let c = *self.dims.last().unwrap();
        assert_eq!(batch.x.len(), rows * self.dims[0], "batch x shape");
        assert_eq!(batch.y_onehot.len(), rows * c, "batch y shape");

        let l_count = self.layers();
        self.forward_scratch(s, params, &batch.x, rows);

        let mask_sum: f32 = batch.mask.iter().sum();
        debug_assert!(mask_sum > 0.0, "all-padded batch");
        let inv = 1.0 / mask_sum;

        let Scratch { acts, delta, prev, probs, gw, gb, wt } = s;

        // dL/dlogits = (softmax − y) / Σmask on real rows, 0 on padding.
        zeroed(delta, rows * c);
        zeroed(probs, c);
        let logits = &acts[l_count - 1];
        let mut loss = 0.0f64;
        for r in 0..rows {
            if batch.mask[r] == 0.0 {
                continue;
            }
            let yr = &batch.y_onehot[r * c..(r + 1) * c];
            let label = yr
                .iter()
                .position(|&v| v == 1.0)
                .expect("one-hot row without a label");
            loss += Self::row_loss(&logits[r * c..(r + 1) * c], label, probs) as f64;
            let dr = &mut delta[r * c..(r + 1) * c];
            for j in 0..c {
                dr[j] = (probs[j] - yr[j]) * inv;
            }
        }
        let loss = (loss * inv as f64) as f32;

        // Backward + SGD, layer by layer from the top. Parameters are
        // updated in place only after everything that reads their
        // pre-step values (this layer's wT, the forward activations)
        // has been consumed.
        for l in (0..l_count).rev() {
            let (in_d, out_d) = (self.dims[l], self.dims[l + 1]);

            // gw = a_inᵀ @ delta, gb = Σ_rows delta
            zeroed(gw, in_d * out_d);
            zeroed(gb, out_d);
            for r in 0..rows {
                let dr = &delta[r * out_d..(r + 1) * out_d];
                let ar: &[f32] = if l == 0 {
                    &batch.x[r * in_d..(r + 1) * in_d]
                } else {
                    &acts[l - 1][r * in_d..(r + 1) * in_d]
                };
                for (g, &d) in gb.iter_mut().zip(dr) {
                    *g += d;
                }
                for (i, &ai) in ar.iter().enumerate() {
                    if ai == 0.0 {
                        continue;
                    }
                    let grow = &mut gw[i * out_d..(i + 1) * out_d];
                    for (g, &d) in grow.iter_mut().zip(dr) {
                        *g += ai * d;
                    }
                }
            }

            // delta ← (delta @ wᵀ) ⊙ relu'(a_in) for the layer below,
            // via the cached transposed weights: per row, ascending-j
            // axpy sweeps over contiguous wT rows — the same per-element
            // accumulation order as the scalar dot, but vectorizable.
            if l > 0 {
                let w = &params[2 * l];
                wt.resize(in_d * out_d, 0.0); // fully overwritten below
                for i in 0..in_d {
                    let wrow = &w[i * out_d..(i + 1) * out_d];
                    for (o, &wio) in wrow.iter().enumerate() {
                        wt[o * in_d + i] = wio;
                    }
                }
                zeroed(prev, rows * in_d);
                for r in 0..rows {
                    let dr = &delta[r * out_d..(r + 1) * out_d];
                    let ar = &acts[l - 1][r * in_d..(r + 1) * in_d];
                    let pr = &mut prev[r * in_d..(r + 1) * in_d];
                    for (j, &dj) in dr.iter().enumerate() {
                        let wtr = &wt[j * in_d..(j + 1) * in_d];
                        for (p, &wv) in pr.iter_mut().zip(wtr) {
                            *p += wv * dj;
                        }
                    }
                    // ReLU gate: a closed gate passes no gradient (the
                    // scalar path skipped these sums; overwriting with
                    // the same +0.0 it left behind is bit-identical)
                    for (p, &ai) in pr.iter_mut().zip(ar) {
                        if ai <= 0.0 {
                            *p = 0.0;
                        }
                    }
                }
                std::mem::swap(delta, prev);
            }

            for (p, &g) in params[2 * l].iter_mut().zip(gw.iter()) {
                *p -= lr * g;
            }
            for (p, &g) in params[2 * l + 1].iter_mut().zip(gb.iter()) {
                *p -= lr * g;
            }
        }
        loss
    }

    /// Batched `τ`-epoch minibatch SGD over a **uniform** batch of
    /// learner tasks (same `τ`, same shard length — mixed shapes are an
    /// error; [`crate::runtime::Runtime::train_many`] splits mixed
    /// flushes into uniform runs). Convenience wrapper over
    /// [`Self::train_many_into`] with a fresh [`BatchScratch`].
    pub fn train_many(
        &self,
        tasks: &[TrainTask<'_>],
        data: &Dataset,
        train_batch: usize,
        lr: f32,
    ) -> Result<Vec<TrainOutcome>> {
        let mut s = BatchScratch::new();
        self.train_many_into(&mut s, tasks, data, train_batch, lr)
    }

    /// [`Self::train_many`] through a caller-held [`BatchScratch`].
    ///
    /// Runs the whole batch **layer-synchronously**: per minibatch step
    /// all learners' layer-`l` matmuls execute as one batched pass over
    /// the stripe buffers ([`matmul_bias_rows`] /
    /// [`grad_weights_rows`] — `ROW_BLOCK × TILE` register panels),
    /// then the next layer. Each learner trains from its own parameter
    /// snapshot on its own shard, and per learner the arithmetic is
    /// **exactly** the [`crate::runtime::Runtime::train_epochs`]
    /// sequence — same accumulation order, same zero-skips, same f64
    /// loss averaging — so in the default build the outcome is
    /// bit-identical to running the tasks one at a time, for every
    /// batch size (the `rust/tests/batched_backend.rs` differential).
    /// Under `fast-numerics` the batched kernels use FMA without the
    /// zero-skips; results stay deterministic and batch-size-invariant
    /// (the kernels are per-stripe), but differ from the default bits
    /// within the tolerance contract.
    ///
    /// `τ = 0` or an empty shard reproduces the per-learner semantics:
    /// the snapshot is returned untouched with a NaN loss.
    pub fn train_many_into(
        &self,
        s: &mut BatchScratch,
        tasks: &[TrainTask<'_>],
        data: &Dataset,
        train_batch: usize,
        lr: f32,
    ) -> Result<Vec<TrainOutcome>> {
        let nb = tasks.len();
        if nb == 0 {
            return Ok(Vec::new());
        }
        ensure!(train_batch > 0, "train_batch must be positive");
        let tau = tasks[0].tau;
        let d = tasks[0].shard.len();
        for (i, t) in tasks.iter().enumerate() {
            ensure!(
                t.tau == tau && t.shard.len() == d,
                "train_many requires a uniform batch: task {i} is (tau={}, d={}) vs task 0 (tau={tau}, d={d})",
                t.tau,
                t.shard.len()
            );
            self.check_params(t.params);
        }
        let mut outs: Vec<TrainOutcome> = tasks
            .iter()
            .map(|t| TrainOutcome { params: t.params.clone(), train_loss: f32::NAN })
            .collect();
        if tau == 0 || d == 0 {
            return Ok(outs);
        }
        let f = data.features;
        let c = *self.dims.last().unwrap();
        ensure!(f == self.dims[0], "dataset features {f} != input dim {}", self.dims[0]);
        ensure!(data.classes == c, "dataset classes {} != output dim {c}", data.classes);

        let b = train_batch;
        let steps = d.div_ceil(b);
        let mut loss_sum = vec![0.0f64; nb];
        for _epoch in 0..tau {
            for v in loss_sum.iter_mut() {
                *v = 0.0;
            }
            for step in 0..steps {
                let lo = step * b;
                let real = (d - lo).min(b);
                self.gather_batch(s, tasks, data, lo, real, b);
                self.train_step_batched(s, &mut outs, b, lr);
                for (ls, &l) in loss_sum.iter_mut().zip(&s.step_loss) {
                    *ls += l as f64;
                }
            }
        }
        for (o, &ls) in outs.iter_mut().zip(&loss_sum) {
            o.train_loss = (ls / steps as f64) as f32;
        }
        Ok(outs)
    }

    /// Gather every learner's current minibatch into the stripe buffers
    /// — exactly the rows, one-hots and mask `Minibatches` would have
    /// produced for `shard[lo..lo + real]` padded to `b` rows, minus
    /// the three per-step `Vec` allocations.
    fn gather_batch(
        &self,
        s: &mut BatchScratch,
        tasks: &[TrainTask<'_>],
        data: &Dataset,
        lo: usize,
        real: usize,
        b: usize,
    ) {
        let nb = tasks.len();
        let f = data.features;
        let c = data.classes;
        s.x.resize(nb * b * f, 0.0);
        // one-hots and mask are cheap to clear fully; x only needs its
        // pad rows re-zeroed (real rows are overwritten below, pad rows
        // from earlier steps were already zero)
        zeroed(&mut s.y, nb * b * c);
        zeroed(&mut s.mask, nb * b);
        for (bi, t) in tasks.iter().enumerate() {
            let xs = &mut s.x[bi * b * f..(bi + 1) * b * f];
            xs[real * f..].fill(0.0);
            let ys = &mut s.y[bi * b * c..(bi + 1) * b * c];
            let ms = &mut s.mask[bi * b..(bi + 1) * b];
            for (row, &idx) in t.shard[lo..lo + real].iter().enumerate() {
                xs[row * f..(row + 1) * f].copy_from_slice(data.row(idx as usize));
                ys[row * c + data.y[idx as usize] as usize] = 1.0;
                ms[row] = 1.0;
            }
        }
    }

    /// One layer-synchronous batched SGD step over all stripes: the
    /// [`Self::train_step_into`] control flow with the learner loop
    /// pulled inside each per-layer phase. Per-learner masked mean
    /// losses land in `s.step_loss`.
    fn train_step_batched(&self, s: &mut BatchScratch, outs: &mut [TrainOutcome], rows: usize, lr: f32) {
        let nb = outs.len();
        let l_count = self.layers();
        let c = *self.dims.last().unwrap();

        // batched forward: one pass per layer across all stripes
        {
            let BatchScratch { acts, x, .. } = s;
            while acts.len() < l_count {
                acts.push(Vec::new());
            }
            for l in 0..l_count {
                let (in_d, out_d) = (self.dims[l], self.dims[l + 1]);
                let (below, rest) = acts.split_at_mut(l);
                let z = &mut rest[0];
                z.resize(nb * rows * out_d, 0.0);
                for (bi, o) in outs.iter().enumerate() {
                    let input: &[f32] = if l == 0 {
                        &x[bi * rows * in_d..(bi + 1) * rows * in_d]
                    } else {
                        &below[l - 1][bi * rows * in_d..(bi + 1) * rows * in_d]
                    };
                    matmul_bias_rows(
                        &mut z[bi * rows * out_d..(bi + 1) * rows * out_d],
                        input,
                        &o.params[2 * l],
                        &o.params[2 * l + 1],
                        rows,
                        in_d,
                        out_d,
                    );
                }
                if l + 1 < l_count {
                    for v in z.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
        }

        // per-stripe softmax-CE loss + dL/dlogits
        {
            let BatchScratch { acts, delta, probs, mask, y, step_loss, .. } = s;
            zeroed(delta, nb * rows * c);
            zeroed(probs, c);
            zeroed(step_loss, nb);
            let logits = &acts[l_count - 1];
            for bi in 0..nb {
                let mrow = &mask[bi * rows..(bi + 1) * rows];
                let mask_sum: f32 = mrow.iter().sum();
                debug_assert!(mask_sum > 0.0, "all-padded stripe");
                let inv = 1.0 / mask_sum;
                let mut loss = 0.0f64;
                for r in 0..rows {
                    if mrow[r] == 0.0 {
                        continue;
                    }
                    let yr = &y[(bi * rows + r) * c..(bi * rows + r + 1) * c];
                    let label = yr
                        .iter()
                        .position(|&v| v == 1.0)
                        .expect("one-hot row without a label");
                    loss += Self::row_loss(
                        &logits[(bi * rows + r) * c..(bi * rows + r + 1) * c],
                        label,
                        probs,
                    ) as f64;
                    let dr = &mut delta[(bi * rows + r) * c..(bi * rows + r + 1) * c];
                    for j in 0..c {
                        dr[j] = (probs[j] - yr[j]) * inv;
                    }
                }
                step_loss[bi] = (loss * inv as f64) as f32;
            }
        }

        // batched backward + in-place SGD, layer by layer from the top;
        // within a layer each stripe computes gw/gb, backprops its delta
        // and updates its own parameters — the per-learner order — with
        // the row-blocked gradient kernel.
        let BatchScratch { acts, delta, prev, gw, gb, wt, x, .. } = s;
        for l in (0..l_count).rev() {
            let (in_d, out_d) = (self.dims[l], self.dims[l + 1]);
            if l > 0 {
                zeroed(prev, nb * rows * in_d);
            }
            for (bi, o) in outs.iter_mut().enumerate() {
                let dstripe = &delta[bi * rows * out_d..(bi + 1) * rows * out_d];
                let astripe: &[f32] = if l == 0 {
                    &x[bi * rows * in_d..(bi + 1) * rows * in_d]
                } else {
                    &acts[l - 1][bi * rows * in_d..(bi + 1) * rows * in_d]
                };
                zeroed(gw, in_d * out_d);
                zeroed(gb, out_d);
                for r in 0..rows {
                    let dr = &dstripe[r * out_d..(r + 1) * out_d];
                    for (g, &dv) in gb.iter_mut().zip(dr) {
                        *g += dv;
                    }
                }
                grad_weights_rows(gw, astripe, dstripe, rows, in_d, out_d);
                if l > 0 {
                    let w = &o.params[2 * l];
                    wt.resize(in_d * out_d, 0.0); // fully overwritten below
                    for i in 0..in_d {
                        let wrow = &w[i * out_d..(i + 1) * out_d];
                        for (oj, &wio) in wrow.iter().enumerate() {
                            wt[oj * in_d + i] = wio;
                        }
                    }
                    let pstripe = &mut prev[bi * rows * in_d..(bi + 1) * rows * in_d];
                    for r in 0..rows {
                        let dr = &dstripe[r * out_d..(r + 1) * out_d];
                        let ar = &astripe[r * in_d..(r + 1) * in_d];
                        let pr = &mut pstripe[r * in_d..(r + 1) * in_d];
                        for (j, &dj) in dr.iter().enumerate() {
                            let wtr = &wt[j * in_d..(j + 1) * in_d];
                            for (p, &wv) in pr.iter_mut().zip(wtr) {
                                *p += wv * dj;
                            }
                        }
                        for (p, &ai) in pr.iter_mut().zip(ar) {
                            if ai <= 0.0 {
                                *p = 0.0;
                            }
                        }
                    }
                }
                for (p, &g) in o.params[2 * l].iter_mut().zip(gw.iter()) {
                    *p -= lr * g;
                }
                for (p, &g) in o.params[2 * l + 1].iter_mut().zip(gb.iter()) {
                    *p -= lr * g;
                }
            }
            if l > 0 {
                std::mem::swap(delta, prev);
            }
        }
    }

    /// One eval minibatch; mirrors the AOT `eval_step` contract:
    /// `(correct, loss_sum, mask_sum)` over the real rows.
    /// Wrapper over [`Self::eval_batch_with`]; streaming callers keep
    /// one [`Scratch`] across batches.
    pub fn eval_batch(&self, params: &ParamSet, batch: &Batch) -> (f64, f64, f64) {
        let mut scratch = Scratch::new();
        self.eval_batch_with(&mut scratch, params, batch)
    }

    /// [`Self::eval_batch`] through a caller-held [`Scratch`] —
    /// allocation-free after the scratch's first use, and the input
    /// batch is borrowed rather than cloned into the activation stack.
    pub fn eval_batch_with(
        &self,
        s: &mut Scratch,
        params: &ParamSet,
        batch: &Batch,
    ) -> (f64, f64, f64) {
        self.check_params(params);
        let rows = batch.mask.len();
        let c = *self.dims.last().unwrap();
        self.forward_scratch(s, params, &batch.x, rows);
        let Scratch { acts, probs, .. } = s;
        let logits = &acts[self.layers() - 1];
        zeroed(probs, c);
        let (mut correct, mut loss_sum, mut mask_sum) = (0.0f64, 0.0f64, 0.0f64);
        for r in 0..rows {
            if batch.mask[r] == 0.0 {
                continue;
            }
            let yr = &batch.y_onehot[r * c..(r + 1) * c];
            let label = yr
                .iter()
                .position(|&v| v == 1.0)
                .expect("one-hot row without a label");
            let zr = &logits[r * c..(r + 1) * c];
            loss_sum += Self::row_loss(zr, label, probs) as f64;
            let pred = zr
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            if pred == label {
                correct += 1.0;
            }
            mask_sum += 1.0;
        }
        (correct, loss_sum, mask_sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Minibatches, SynthConfig};
    use crate::sim::Rng;

    /// The pre-optimization executor, kept verbatim as the differential
    /// oracle for the scratch/tile/transpose rewrite: the optimized hot
    /// path must reproduce it **bit for bit** on every shape, including
    /// padded rows and exact zeros in inputs/activations.
    mod reference {
        use super::*;

        fn matmul_bias(
            x: &[f32],
            w: &[f32],
            b: &[f32],
            rows: usize,
            in_d: usize,
            out_d: usize,
        ) -> Vec<f32> {
            let mut out = vec![0.0f32; rows * out_d];
            for r in 0..rows {
                let xr = &x[r * in_d..(r + 1) * in_d];
                let or = &mut out[r * out_d..(r + 1) * out_d];
                or.copy_from_slice(b);
                for (i, &xi) in xr.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    let wrow = &w[i * out_d..(i + 1) * out_d];
                    for (o, &wij) in or.iter_mut().zip(wrow) {
                        *o += xi * wij;
                    }
                }
            }
            out
        }

        fn forward(dims: &[usize], params: &ParamSet, x: &[f32], rows: usize) -> Vec<Vec<f32>> {
            let l_count = dims.len() - 1;
            let mut acts: Vec<Vec<f32>> = Vec::with_capacity(l_count + 1);
            acts.push(x.to_vec());
            for l in 0..l_count {
                let mut z = matmul_bias(
                    &acts[l],
                    &params[2 * l],
                    &params[2 * l + 1],
                    rows,
                    dims[l],
                    dims[l + 1],
                );
                if l + 1 < l_count {
                    for v in &mut z {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                acts.push(z);
            }
            acts
        }

        pub fn train_step(
            dims: &[usize],
            params: &ParamSet,
            batch: &Batch,
            lr: f32,
        ) -> (ParamSet, f32) {
            let rows = batch.mask.len();
            let c = *dims.last().unwrap();
            let l_count = dims.len() - 1;
            let acts = forward(dims, params, &batch.x, rows);
            let logits = &acts[l_count];
            let mask_sum: f32 = batch.mask.iter().sum();
            let inv = 1.0 / mask_sum;
            let mut delta = vec![0.0f32; rows * c];
            let mut probs = vec![0.0f32; c];
            let mut loss = 0.0f64;
            for r in 0..rows {
                if batch.mask[r] == 0.0 {
                    continue;
                }
                let yr = &batch.y_onehot[r * c..(r + 1) * c];
                let label = yr.iter().position(|&v| v == 1.0).unwrap();
                loss +=
                    NativeExecutor::row_loss(&logits[r * c..(r + 1) * c], label, &mut probs)
                        as f64;
                let dr = &mut delta[r * c..(r + 1) * c];
                for j in 0..c {
                    dr[j] = (probs[j] - yr[j]) * inv;
                }
            }
            let loss = (loss * inv as f64) as f32;
            let mut new_params = params.clone();
            for l in (0..l_count).rev() {
                let (in_d, out_d) = (dims[l], dims[l + 1]);
                let a_in = &acts[l];
                let w = &params[2 * l];
                let mut gw = vec![0.0f32; in_d * out_d];
                let mut gb = vec![0.0f32; out_d];
                for r in 0..rows {
                    let dr = &delta[r * out_d..(r + 1) * out_d];
                    let ar = &a_in[r * in_d..(r + 1) * in_d];
                    for (g, &d) in gb.iter_mut().zip(dr) {
                        *g += d;
                    }
                    for (i, &ai) in ar.iter().enumerate() {
                        if ai == 0.0 {
                            continue;
                        }
                        let grow = &mut gw[i * out_d..(i + 1) * out_d];
                        for (g, &d) in grow.iter_mut().zip(dr) {
                            *g += ai * d;
                        }
                    }
                }
                if l > 0 {
                    let mut prev = vec![0.0f32; rows * in_d];
                    for r in 0..rows {
                        let dr = &delta[r * out_d..(r + 1) * out_d];
                        let ar = &a_in[r * in_d..(r + 1) * in_d];
                        let pr = &mut prev[r * in_d..(r + 1) * in_d];
                        for i in 0..in_d {
                            if ar[i] <= 0.0 {
                                continue;
                            }
                            let wrow = &w[i * out_d..(i + 1) * out_d];
                            let mut s = 0.0f32;
                            for (wj, &dj) in wrow.iter().zip(dr) {
                                s += wj * dj;
                            }
                            pr[i] = s;
                        }
                    }
                    delta = prev;
                }
                for (p, &g) in new_params[2 * l].iter_mut().zip(&gw) {
                    *p -= lr * g;
                }
                for (p, &g) in new_params[2 * l + 1].iter_mut().zip(&gb) {
                    *p -= lr * g;
                }
            }
            (new_params, loss)
        }

        pub fn eval_batch(
            dims: &[usize],
            params: &ParamSet,
            batch: &Batch,
        ) -> (f64, f64, f64) {
            let rows = batch.mask.len();
            let c = *dims.last().unwrap();
            let l_count = dims.len() - 1;
            let acts = forward(dims, params, &batch.x, rows);
            let logits = &acts[l_count];
            let mut probs = vec![0.0f32; c];
            let (mut correct, mut loss_sum, mut mask_sum) = (0.0f64, 0.0f64, 0.0f64);
            for r in 0..rows {
                if batch.mask[r] == 0.0 {
                    continue;
                }
                let yr = &batch.y_onehot[r * c..(r + 1) * c];
                let label = yr.iter().position(|&v| v == 1.0).unwrap();
                let zr = &logits[r * c..(r + 1) * c];
                loss_sum += NativeExecutor::row_loss(zr, label, &mut probs) as f64;
                let pred = zr
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if pred == label {
                    correct += 1.0;
                }
                mask_sum += 1.0;
            }
            (correct, loss_sum, mask_sum)
        }
    }

    fn tiny_dims() -> Vec<usize> {
        vec![36, 16, 4]
    }

    fn he_params(dims: &[usize], rng: &mut Rng) -> ParamSet {
        let mut out = Vec::new();
        for l in 0..dims.len() - 1 {
            let std = (2.0 / dims[l] as f64).sqrt();
            out.push(
                (0..dims[l] * dims[l + 1])
                    .map(|_| rng.normal_ms(0.0, std) as f32)
                    .collect(),
            );
            out.push(vec![0.0f32; dims[l + 1]]);
        }
        out
    }

    fn tiny_data() -> crate::data::SynthDataset {
        synth::generate(&SynthConfig {
            side: 6,
            classes: 4,
            train: 128,
            test: 64,
            noise_std: 0.4,
            ..SynthConfig::default()
        })
    }

    /// A random batch with `pad` padded tail rows and some exact-zero
    /// inputs (the zero-skip paths must agree with the reference too).
    fn random_batch(rows: usize, pad: usize, f: usize, c: usize, rng: &mut Rng) -> Batch {
        let total = rows + pad;
        let mut x: Vec<f32> = (0..total * f).map(|_| rng.normal() as f32).collect();
        for v in x.iter_mut() {
            if rng.below(7) == 0 {
                *v = 0.0;
            }
        }
        let mut y = vec![0.0f32; total * c];
        let mut mask = vec![0.0f32; total];
        for r in 0..rows {
            y[r * c + rng.below(c as u64) as usize] = 1.0;
            mask[r] = 1.0;
        }
        for r in rows..total {
            y[r * c] = 1.0; // padded rows still need a valid one-hot
        }
        Batch { x, y_onehot: y, mask, real: rows }
    }

    fn assert_params_bitwise(a: &ParamSet, b: &ParamSet, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: tensor count");
        for (ti, (ta, tb)) in a.iter().zip(b).enumerate() {
            assert_eq!(ta.len(), tb.len(), "{what}: tensor {ti} len");
            for (vi, (va, vb)) in ta.iter().zip(tb).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{what}: tensor {ti}[{vi}]: {va} vs {vb}"
                );
            }
        }
    }

    #[test]
    fn optimized_train_step_is_bit_identical_to_the_reference() {
        // every structural case: single hidden, deep stack, wide output
        // (multiple register tiles), padded rows, zero inputs, scratch
        // reuse across differing shapes in one Scratch
        let shapes: Vec<(Vec<usize>, usize, usize)> = vec![
            (vec![7, 5, 3], 4, 0),
            (vec![36, 16, 4], 9, 3),
            (vec![9, 8, 6, 5], 6, 2),
            (vec![12, 40, 3], 5, 1), // out_d 40 > TILE: several tiles
        ];
        let mut rng = Rng::new(0xD1FF);
        let mut scratch = Scratch::new();
        for (dims, rows, pad) in shapes {
            let exec = NativeExecutor::new(&dims);
            let params = he_params(&dims, &mut rng);
            let batch = random_batch(rows, pad, dims[0], *dims.last().unwrap(), &mut rng);
            for lr in [0.0f32, 0.1, 1.0] {
                let (p_ref, l_ref) = reference::train_step(&dims, &params, &batch, lr);
                // wrapper path
                let (p_new, l_new) = exec.train_step(&params, &batch, lr);
                assert_eq!(l_ref.to_bits(), l_new.to_bits(), "{dims:?} lr {lr}: loss");
                assert_params_bitwise(&p_ref, &p_new, &format!("{dims:?} lr {lr}"));
                // in-place path through a reused scratch
                let mut p_inplace = params.clone();
                let l_in = exec.train_step_into(&mut scratch, &mut p_inplace, &batch, lr);
                assert_eq!(l_ref.to_bits(), l_in.to_bits(), "{dims:?} lr {lr}: loss (in-place)");
                assert_params_bitwise(&p_ref, &p_inplace, &format!("{dims:?} lr {lr} in-place"));
            }
        }
    }

    #[test]
    fn optimized_eval_is_bit_identical_to_the_reference() {
        // the eval path must not regress from the borrow-instead-of-
        // clone rewrite: same counts, same loss bits, scratch reused
        let mut rng = Rng::new(0xE7A1);
        let mut scratch = Scratch::new();
        for (dims, rows, pad) in [
            (vec![7usize, 5, 3], 6usize, 2usize),
            (vec![36, 16, 4], 12, 0),
            (vec![9, 8, 6, 5], 5, 4),
        ] {
            let exec = NativeExecutor::new(&dims);
            let params = he_params(&dims, &mut rng);
            let batch = random_batch(rows, pad, dims[0], *dims.last().unwrap(), &mut rng);
            let (c_ref, l_ref, m_ref) = reference::eval_batch(&dims, &params, &batch);
            let (c_new, l_new, m_new) = exec.eval_batch(&params, &batch);
            assert_eq!(c_ref, c_new, "{dims:?}: correct");
            assert_eq!(l_ref.to_bits(), l_new.to_bits(), "{dims:?}: loss bits");
            assert_eq!(m_ref, m_new, "{dims:?}: mask sum");
            let (c_s, l_s, m_s) = exec.eval_batch_with(&mut scratch, &params, &batch);
            assert_eq!((c_ref, m_ref), (c_s, m_s), "{dims:?}: scratch path counts");
            assert_eq!(l_ref.to_bits(), l_s.to_bits(), "{dims:?}: scratch path loss");
        }
    }

    #[test]
    fn scratch_reuse_does_not_leak_state_between_steps() {
        // run a big shape, then a smaller one: stale high-water data in
        // the recycled buffers must not bleed into the smaller step
        let mut rng = Rng::new(0xBEEF);
        let mut scratch = Scratch::new();
        let big_dims = vec![12usize, 40, 3];
        let big = NativeExecutor::new(&big_dims);
        let big_params = he_params(&big_dims, &mut rng);
        let big_batch = random_batch(8, 0, 12, 3, &mut rng);
        let mut p = big_params.clone();
        big.train_step_into(&mut scratch, &mut p, &big_batch, 0.1);

        let dims = vec![7usize, 5, 3];
        let exec = NativeExecutor::new(&dims);
        let params = he_params(&dims, &mut rng);
        let batch = random_batch(4, 1, 7, 3, &mut rng);
        let (p_ref, l_ref) = reference::train_step(&dims, &params, &batch, 0.2);
        let mut p_new = params.clone();
        let l_new = exec.train_step_into(&mut scratch, &mut p_new, &batch, 0.2);
        assert_eq!(l_ref.to_bits(), l_new.to_bits());
        assert_params_bitwise(&p_ref, &p_new, "after big->small scratch reuse");
        let (c_ref, le_ref, m_ref) = reference::eval_batch(&dims, &params, &batch);
        let (c_new, le_new, m_new) = exec.eval_batch_with(&mut scratch, &params, &batch);
        assert_eq!((c_ref, m_ref), (c_new, m_new));
        assert_eq!(le_ref.to_bits(), le_new.to_bits());
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(11);
        let mut params = he_params(&dims, &mut rng);
        let idx: Vec<u32> = (0..64).collect();
        let batch = Minibatches::new(&ds.train, &idx, 64).next().unwrap();
        let (_, loss0) = exec.train_step(&params, &batch, 0.2);
        let mut last = loss0;
        for _ in 0..30 {
            let (next, loss) = exec.train_step(&params, &batch, 0.2);
            params = next;
            last = loss;
        }
        assert!(last < loss0 * 0.7, "loss did not drop: {loss0} -> {last}");
        for t in &params {
            assert!(t.iter().all(|v| v.is_finite()), "NaN/Inf in params");
        }
    }

    #[test]
    fn untrained_eval_is_chance_level_and_counts_mask() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(5);
        let params = he_params(&dims, &mut rng);
        let idx: Vec<u32> = (0..64).collect();
        let mut correct = 0.0;
        let mut n = 0.0;
        for batch in Minibatches::new(&ds.test, &idx, 48) {
            let (c, l, m) = exec.eval_batch(&params, &batch);
            assert!(l.is_finite() && l > 0.0);
            correct += c;
            n += m;
        }
        assert_eq!(n, 64.0, "mask sum must count only real rows");
        let acc = correct / n;
        assert!((0.0..0.8).contains(&acc), "untrained accuracy {acc}");
    }

    #[test]
    fn padded_rows_do_not_contribute_gradient() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(7);
        let params = he_params(&dims, &mut rng);
        // 10 real rows padded to 32 vs exactly 10 rows: identical update
        let idx: Vec<u32> = (0..10).collect();
        let padded = Minibatches::new(&ds.train, &idx, 32).next().unwrap();
        let tight = Minibatches::new(&ds.train, &idx, 10).next().unwrap();
        let (p_pad, l_pad) = exec.train_step(&params, &padded, 0.1);
        let (p_tight, l_tight) = exec.train_step(&params, &tight, 0.1);
        assert_eq!(l_pad, l_tight);
        for (a, b) in p_pad.iter().zip(&p_tight) {
            assert_eq!(a, b, "padding changed the update");
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // spot-check dL/dw on a few coordinates via central differences
        let dims = vec![6, 5, 3];
        let exec = NativeExecutor::new(&dims);
        let mut rng = Rng::new(3);
        let params = he_params(&dims, &mut rng);
        let rows = 4usize;
        let x: Vec<f32> = (0..rows * 6).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; rows * 3];
        for r in 0..rows {
            y[r * 3 + r % 3] = 1.0;
        }
        let batch = Batch { x, y_onehot: y, mask: vec![1.0; rows], real: rows };

        let loss_at = |p: &ParamSet| -> f64 {
            let (_, loss_sum, mask_sum) = exec.eval_batch(p, &batch);
            loss_sum / mask_sum
        };
        let lr = 1.0f32; // step == gradient, so (params - new) = grad
        let (stepped, _) = exec.train_step(&params, &batch, lr);
        let eps = 1e-3f32;
        for (ti, vi) in [(0usize, 1usize), (1, 2), (2, 4), (3, 0)] {
            let analytic = params[ti][vi] - stepped[ti][vi];
            let mut plus = params.clone();
            plus[ti][vi] += eps;
            let mut minus = params.clone();
            minus[ti][vi] -= eps;
            let numeric = ((loss_at(&plus) - loss_at(&minus)) / (2.0 * eps as f64)) as f32;
            assert!(
                (analytic - numeric).abs() < 2e-2_f32.max(0.2 * numeric.abs()),
                "tensor {ti}[{vi}]: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn training_learns_separable_clusters() {
        let dims = tiny_dims();
        let exec = NativeExecutor::new(&dims);
        let ds = tiny_data();
        let mut rng = Rng::new(19);
        let mut params = he_params(&dims, &mut rng);
        let idx: Vec<u32> = (0..ds.train.len() as u32).collect();
        let mut scratch = Scratch::new();
        for _epoch in 0..20 {
            for batch in Minibatches::new(&ds.train, &idx, 32) {
                exec.train_step_into(&mut scratch, &mut params, &batch, 0.2);
            }
        }
        let test_idx: Vec<u32> = (0..ds.test.len() as u32).collect();
        let (mut correct, mut n) = (0.0, 0.0);
        for batch in Minibatches::new(&ds.test, &test_idx, 32) {
            let (c, _, m) = exec.eval_batch(&params, &batch);
            correct += c;
            n += m;
        }
        let acc = correct / n;
        assert!(acc > 0.6, "trained accuracy {acc} (chance 0.25)");
    }
}
