//! Minimal benchmarking harness (the registry has no criterion — see
//! Cargo.toml). Warmup + timed iterations, robust summary statistics,
//! aligned reporting. All `rust/benches/*` targets use this with
//! `harness = false`.
//!
//! Machine-readable output: wrap the cases in a [`BenchRun`] and every
//! target grows two passthrough flags (`cargo bench --bench X -- …`):
//!
//! * `--json PATH` — write the collected [`BenchResult`]s as JSON
//!   (`BENCH_<target>.json` by convention; `scripts/bench_check.sh`
//!   gates CI on them against `rust/benches/baseline.json`);
//! * `--smoke` — shrink warmup/measure budgets to a fast CI smoke
//!   config (targets also gate their expensive regeneration sweeps on
//!   [`BenchRun::smoke`]).
//!
//! `BENCH_SMOKE=1` / `BENCH_JSON=PATH` env vars are honored as
//! fallbacks for runners that cannot pass arguments through.

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::json::Value;

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup wall-time budget.
    pub warmup: Duration,
    /// Measurement wall-time budget.
    pub measure: Duration,
    /// Hard cap on measured iterations (for very slow cases).
    pub max_iters: usize,
    /// Minimum measured iterations.
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl BenchConfig {
    /// Config for expensive end-to-end cases (seconds per iteration).
    pub fn slow() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_secs(10),
            max_iters: 10,
            min_iters: 2,
        }
    }
}

/// Summary statistics over per-iteration times (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pick = |q: f64| samples[((n as f64 - 1.0) * q).round() as usize];
        Stats {
            iters: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            median_s: pick(0.5),
            p95_s: pick(0.95),
            min_s: samples[0],
            max_s: samples[n - 1],
        }
    }
}

/// Human-friendly time formatting.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Run one benchmark case: warm up, then measure until the time budget
/// or iteration cap is hit. The closure's return value is black-boxed.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> Stats {
    // warmup
    let t0 = Instant::now();
    while t0.elapsed() < cfg.warmup {
        std::hint::black_box(f());
    }
    // measure
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while (t0.elapsed() < cfg.measure && samples.len() < cfg.max_iters)
        || samples.len() < cfg.min_iters
    {
        let it = Instant::now();
        std::hint::black_box(f());
        samples.push(it.elapsed().as_secs_f64());
    }
    let stats = Stats::from_samples(samples);
    println!(
        "{:<44} {:>10}/iter  (median {:>10}, p95 {:>10}, n={})",
        name,
        fmt_time(stats.mean_s),
        fmt_time(stats.median_s),
        fmt_time(stats.p95_s),
        stats.iters
    );
    stats
}

/// Group header, criterion-style.
pub fn group(title: &str) {
    println!("\n--- {title} ---");
}

/// One named measurement plus the config it ran under — the
/// machine-readable unit `scripts/bench_check.sh` consumes.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub config: String,
    pub stats: Stats,
}

impl BenchResult {
    /// Flatten to JSON (times in nanoseconds).
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", self.name.as_str())
            .set("config", self.config.as_str())
            .set("iters", self.stats.iters)
            .set("mean_ns", self.stats.mean_s * 1e9)
            .set("p50_ns", self.stats.median_s * 1e9)
            .set("p95_ns", self.stats.p95_s * 1e9)
            .set("min_ns", self.stats.min_s * 1e9)
            .set("max_ns", self.stats.max_s * 1e9);
        v
    }
}

/// Per-target collector: parses `--smoke` / `--json PATH` from the
/// process arguments, wraps [`bench`], and writes the JSON report on
/// [`BenchRun::finish`].
#[derive(Debug)]
pub struct BenchRun {
    pub target: String,
    smoke: bool,
    json_path: Option<String>,
    results: Vec<BenchResult>,
}

impl BenchRun {
    /// Build from the process arguments (+ `BENCH_SMOKE` / `BENCH_JSON`
    /// env fallbacks). `target` names the bench binary.
    pub fn from_env(target: &str) -> BenchRun {
        let mut smoke = false;
        let mut json_path: Option<String> = None;
        let mut it = std::env::args().skip(1);
        while let Some(tok) = it.next() {
            if tok == "--smoke" {
                smoke = true;
            } else if tok == "--json" {
                json_path = it.next();
            } else if let Some(p) = tok.strip_prefix("--json=") {
                json_path = Some(p.to_string());
            }
        }
        if let Some(v) = std::env::var_os("BENCH_SMOKE") {
            if !v.is_empty() && v != "0" {
                smoke = true;
            }
        }
        if json_path.is_none() {
            json_path = std::env::var_os("BENCH_JSON")
                .map(|v| v.to_string_lossy().into_owned());
        }
        BenchRun { target: target.to_string(), smoke, json_path, results: Vec::new() }
    }

    /// Smoke mode: targets use this to skip/shrink their expensive
    /// regeneration sweeps, and [`Self::bench`] shrinks time budgets.
    pub fn smoke(&self) -> bool {
        self.smoke
    }

    /// The effective measurement config: unchanged normally, a fast
    /// smoke setting when `--smoke` is active.
    pub fn tuned(&self, cfg: &BenchConfig) -> BenchConfig {
        if !self.smoke {
            return *cfg;
        }
        BenchConfig {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(80),
            max_iters: 5,
            min_iters: 1,
        }
    }

    /// Run + record one case (see [`bench`]).
    pub fn bench<T>(&mut self, name: &str, cfg: &BenchConfig, f: impl FnMut() -> T) -> Stats {
        let cfg = self.tuned(cfg);
        let stats = bench(name, &cfg, f);
        self.results.push(BenchResult {
            name: name.to_string(),
            config: format!(
                "warmup={:?} measure={:?} iters=[{},{}] smoke={}",
                cfg.warmup, cfg.measure, cfg.min_iters, cfg.max_iters, self.smoke
            ),
            stats,
        });
        stats
    }

    /// The full report as JSON.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("target", self.target.as_str()).set("smoke", self.smoke).set(
            "results",
            Value::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        );
        v
    }

    /// Write the JSON report when `--json PATH` (or `BENCH_JSON`) was
    /// given; no-op otherwise.
    pub fn finish(&self) -> Result<()> {
        if let Some(path) = &self.json_path {
            std::fs::write(path, self.to_json().pretty())
                .with_context(|| format!("writing bench JSON {path}"))?;
            println!("bench json -> {path}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            max_iters: 1000,
            min_iters: 3,
        };
        let stats = bench("noop", &cfg, || 1 + 1);
        assert!(stats.iters >= 3);
        assert!(stats.mean_s >= 0.0);
        assert!(stats.min_s <= stats.median_s && stats.median_s <= stats.max_s);
    }

    #[test]
    fn min_iters_enforced_for_slow_cases() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(1),
            max_iters: 100,
            min_iters: 4,
        };
        let stats = bench("sleepy", &cfg, || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(stats.iters >= 4);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains(" s"));
    }

    #[test]
    fn bench_result_serializes_times_in_ns() {
        let r = BenchResult {
            name: "solver/k20".to_string(),
            config: "smoke=false".to_string(),
            stats: Stats {
                iters: 3,
                mean_s: 2.5e-3,
                median_s: 2.0e-3,
                p95_s: 4.0e-3,
                min_s: 1.0e-3,
                max_s: 5.0e-3,
            },
        };
        let v = r.to_json();
        assert_eq!(v.str_field("name").unwrap(), "solver/k20");
        assert_eq!(v.u64_field("iters").unwrap(), 3);
        assert!((v.f64_field("mean_ns").unwrap() - 2.5e6).abs() < 1e-6);
        assert!((v.f64_field("min_ns").unwrap() - 1.0e6).abs() < 1e-6);
        // round-trips through the JSON substrate
        let text = v.pretty();
        let back = crate::json::parse(&text).unwrap();
        assert_eq!(back.str_field("name").unwrap(), "solver/k20");
    }

    #[test]
    fn bench_run_collects_and_writes_json() {
        let mut run = BenchRun {
            target: "unit_test".to_string(),
            smoke: true,
            json_path: None,
            results: Vec::new(),
        };
        assert!(run.smoke());
        let cfg = BenchConfig::default();
        let tuned = run.tuned(&cfg);
        assert!(tuned.measure < cfg.measure, "smoke must shrink the budget");
        run.bench("case/a", &cfg, || 40 + 2);
        run.bench("case/b", &cfg, || "x".repeat(8));
        let v = run.to_json();
        assert_eq!(v.str_field("target").unwrap(), "unit_test");
        let results = v.field("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].str_field("name").unwrap(), "case/a");
        assert!(results[1].f64_field("mean_ns").unwrap() >= 0.0);

        // finish() writes the file when a path is set
        let path = std::env::temp_dir().join("asyncmel_benchkit_test.json");
        run.json_path = Some(path.to_string_lossy().into_owned());
        run.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = crate::json::parse(&text).unwrap();
        assert_eq!(back.field("results").unwrap().as_arr().unwrap().len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stats_quantiles_ordered() {
        let s = Stats::from_samples(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 5.0);
        assert_eq!(s.median_s, 3.0);
        assert!(s.p95_s >= s.median_s);
    }
}
