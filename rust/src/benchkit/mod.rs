//! Minimal benchmarking harness (the registry has no criterion — see
//! Cargo.toml). Warmup + timed iterations, robust summary statistics,
//! aligned reporting. All `rust/benches/*` targets use this with
//! `harness = false`.

use std::time::{Duration, Instant};

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup wall-time budget.
    pub warmup: Duration,
    /// Measurement wall-time budget.
    pub measure: Duration,
    /// Hard cap on measured iterations (for very slow cases).
    pub max_iters: usize,
    /// Minimum measured iterations.
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl BenchConfig {
    /// Config for expensive end-to-end cases (seconds per iteration).
    pub fn slow() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_secs(10),
            max_iters: 10,
            min_iters: 2,
        }
    }
}

/// Summary statistics over per-iteration times (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pick = |q: f64| samples[((n as f64 - 1.0) * q).round() as usize];
        Stats {
            iters: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            median_s: pick(0.5),
            p95_s: pick(0.95),
            min_s: samples[0],
            max_s: samples[n - 1],
        }
    }
}

/// Human-friendly time formatting.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Run one benchmark case: warm up, then measure until the time budget
/// or iteration cap is hit. The closure's return value is black-boxed.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> Stats {
    // warmup
    let t0 = Instant::now();
    while t0.elapsed() < cfg.warmup {
        std::hint::black_box(f());
    }
    // measure
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while (t0.elapsed() < cfg.measure && samples.len() < cfg.max_iters)
        || samples.len() < cfg.min_iters
    {
        let it = Instant::now();
        std::hint::black_box(f());
        samples.push(it.elapsed().as_secs_f64());
    }
    let stats = Stats::from_samples(samples);
    println!(
        "{:<44} {:>10}/iter  (median {:>10}, p95 {:>10}, n={})",
        name,
        fmt_time(stats.mean_s),
        fmt_time(stats.median_s),
        fmt_time(stats.p95_s),
        stats.iters
    );
    stats
}

/// Group header, criterion-style.
pub fn group(title: &str) {
    println!("\n--- {title} ---");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            max_iters: 1000,
            min_iters: 3,
        };
        let stats = bench("noop", &cfg, || 1 + 1);
        assert!(stats.iters >= 3);
        assert!(stats.mean_s >= 0.0);
        assert!(stats.min_s <= stats.median_s && stats.median_s <= stats.max_s);
    }

    #[test]
    fn min_iters_enforced_for_slow_cases() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(1),
            max_iters: 100,
            min_iters: 4,
        };
        let stats = bench("sleepy", &cfg, || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(stats.iters >= 4);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains(" s"));
    }

    #[test]
    fn stats_quantiles_ordered() {
        let s = Stats::from_samples(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 5.0);
        assert_eq!(s.median_s, 3.0);
        assert!(s.p95_s >= s.median_s);
    }
}
