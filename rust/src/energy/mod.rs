//! Per-learner energy accounting — the resource the MEC literature the
//! paper builds on ([4], [5]) optimizes alongside delay.
//!
//! The paper's problem (7) is delay-constrained only; this module adds
//! the standard MEC energy model so allocations can be *audited* for
//! energy fairness (and so the energy-budget ablation in
//! `examples/quickstart.rs`-style reports is possible):
//!
//! ```text
//! E_k = E_k^comp + E_k^tx
//! E_k^comp = κ · f_k² · C_m · τ_k · d_k     (CMOS switched-capacitance)
//! E_k^tx   = P_k · (t_k^S + t_k^R)          (radio on-time × power)
//! ```
//!
//! with `κ` the effective switched capacitance (typ. 1e-28 J/cycle/Hz²
//! — [4]). Receive energy is folded into `t_k^S` at the same power
//! (conservative for Wi-Fi where RX ≈ TX power class).

use crate::allocation::Allocation;
use crate::config::Scenario;

/// Energy model constants.
#[derive(Debug, Clone, Copy)]
pub struct EnergyParams {
    /// Effective switched capacitance κ (J · s²/cycles³ scale).
    pub kappa: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self { kappa: 1e-28 }
    }
}

/// Per-learner energy breakdown for one global cycle (joules).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    pub compute_j: f64,
    pub tx_j: f64,
}

impl EnergyReport {
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.tx_j
    }
}

/// Energy of every learner under an allocation.
pub fn audit(scenario: &Scenario, alloc: &Allocation, params: &EnergyParams) -> Vec<EnergyReport> {
    let task = &scenario.config.task;
    scenario
        .devices
        .iter()
        .zip(&scenario.costs)
        .zip(alloc.tau.iter().zip(&alloc.d))
        .map(|((dev, cost), (&tau, &d))| {
            let cycles = task.compute_cycles_per_sample * tau as f64 * d as f64;
            let compute_j = params.kappa * dev.cpu_hz * dev.cpu_hz * cycles;
            // comm time = C¹·d + C⁰ (eq. 1 + eq. 3 combined)
            let t_comm = cost.c1 * d as f64 + cost.c0;
            let tx_j = dev.tx_power_w * t_comm;
            EnergyReport { compute_j, tx_j }
        })
        .collect()
}

/// Jain's fairness index over per-learner total energy: 1 = perfectly
/// even drain, 1/K = one node pays for everything. Battery fairness is
/// the practical concern ETA-style equal batching ignores.
pub fn jain_fairness(reports: &[EnergyReport]) -> f64 {
    let k = reports.len();
    if k == 0 {
        return 1.0;
    }
    let sum: f64 = reports.iter().map(|r| r.total_j()).sum();
    let sum_sq: f64 = reports.iter().map(|r| r.total_j().powi(2)).sum();
    if sum_sq == 0.0 {
        return 1.0;
    }
    sum * sum / (k as f64 * sum_sq)
}

/// Fleet-level summary.
#[derive(Debug, Clone, Copy)]
pub struct EnergySummary {
    pub total_j: f64,
    pub max_j: f64,
    pub fairness: f64,
}

pub fn summarize(reports: &[EnergyReport]) -> EnergySummary {
    EnergySummary {
        total_j: reports.iter().map(|r| r.total_j()).sum(),
        max_j: reports.iter().map(|r| r.total_j()).fold(0.0, f64::max),
        fairness: jain_fairness(reports),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::{make_allocator, AllocatorKind};
    use crate::config::ScenarioConfig;

    fn scenario() -> Scenario {
        ScenarioConfig::paper_default().with_learners(10).build()
    }

    fn alloc(s: &Scenario, kind: AllocatorKind) -> Allocation {
        make_allocator(kind)
            .allocate(&s.costs, s.t_cycle(), s.total_samples(), &s.bounds)
            .unwrap()
    }

    #[test]
    fn energy_is_positive_and_bounded() {
        let s = scenario();
        let a = alloc(&s, AllocatorKind::Sai);
        let reports = audit(&s, &a, &EnergyParams::default());
        assert_eq!(reports.len(), 10);
        for r in &reports {
            assert!(r.compute_j > 0.0, "learning nodes burn compute energy");
            assert!(r.tx_j > 0.0);
            // a phone-class device over a 15 s cycle stays under ~100 J
            assert!(r.total_j() < 100.0, "implausible energy {}", r.total_j());
        }
    }

    #[test]
    fn compute_energy_scales_with_work() {
        let s = scenario();
        let mut a = alloc(&s, AllocatorKind::Sai);
        let base = audit(&s, &a, &EnergyParams::default());
        // double the first learner's epochs -> its compute energy doubles
        a.tau[0] *= 2;
        let doubled = audit(&s, &a, &EnergyParams::default());
        let ratio = doubled[0].compute_j / base[0].compute_j;
        assert!((ratio - 2.0).abs() < 1e-9, "ratio {ratio}");
        assert_eq!(doubled[0].tx_j, base[0].tx_j);
    }

    #[test]
    fn fairness_index_bounds() {
        let even = vec![EnergyReport { compute_j: 1.0, tx_j: 0.0 }; 8];
        assert!((jain_fairness(&even) - 1.0).abs() < 1e-12);
        let mut skewed = vec![EnergyReport { compute_j: 0.0, tx_j: 0.0 }; 8];
        skewed[0].compute_j = 5.0;
        assert!((jain_fairness(&skewed) - 0.125).abs() < 1e-12);
        assert_eq!(jain_fairness(&[]), 1.0);
    }

    #[test]
    fn optimized_allocation_is_fairer_than_eta_on_energy() {
        // ETA gives slow devices the same batch as fast ones, so fast
        // laptops burning f² on more epochs dominate the drain; the
        // optimized allocation moves data toward capability, evening
        // out *time* (t_k = T) and hence roughly the duty cycle.
        let s = scenario();
        let sai = audit(&s, &alloc(&s, AllocatorKind::Sai), &EnergyParams::default());
        let eta = audit(&s, &alloc(&s, AllocatorKind::Eta), &EnergyParams::default());
        let f_sai = jain_fairness(&sai);
        let f_eta = jain_fairness(&eta);
        // not a theorem, but holds comfortably on the paper scenario
        assert!(
            f_sai >= f_eta - 0.05,
            "sai fairness {f_sai} vs eta {f_eta}"
        );
    }

    #[test]
    fn summary_aggregates() {
        let reports = vec![
            EnergyReport { compute_j: 1.0, tx_j: 1.0 },
            EnergyReport { compute_j: 3.0, tx_j: 0.0 },
        ];
        let s = summarize(&reports);
        assert!((s.total_j - 5.0).abs() < 1e-12);
        assert!((s.max_j - 3.0).abs() < 1e-12);
        assert!(s.fairness > 0.5 && s.fairness < 1.0);
    }
}
