//! Per-learner energy accounting — the resource the MEC literature the
//! paper builds on ([4], [5]) optimizes alongside delay, made a
//! first-class constraint by the authors' sequel (arXiv:2012.00143).
//!
//! The source paper's problem (7) is delay-constrained only. This
//! module carries the standard MEC energy model (2012.00143 §II, after
//! [4], [5]):
//!
//! ```text
//! E_k = E_k^comp + E_k^tx
//! E_k^comp = κ · f_k² · C_m · τ_k · d_k     (CMOS switched-capacitance)
//! E_k^tx   = P_k · t_k^R + r · P_k · t_k^S  (radio on-time × power)
//! ```
//!
//! with `κ` the effective switched capacitance (typ. 1e-28 J/cycle/Hz²
//! — [4]) and `r` = [`EnergyParams::rx_power_ratio`] the receive/TX
//! power ratio.
//!
//! # The Wi-Fi conservatism assumption
//!
//! The downlink leg `t_k^S` is *receive* time at the device, so pricing
//! it at full TX power overstates energy on radios whose RX chain is
//! cheaper. The default `rx_power_ratio = 1.0` keeps that conservative
//! fold-in — deliberate for the paper's Wi-Fi setting, where the RX
//! power class is close to TX — and reproduces the pre-ratio audit
//! numbers bit-for-bit (the correction term is exactly `0.0·P·t_k^S`).
//! Cellular/BLE-class radios should set `r < 1`; a noisy receiver in a
//! dense deployment may even warrant `r > 1`.
//!
//! Three consumers:
//!
//! * **Audit** — [`audit`] prices a finished [`Allocation`] per learner
//!   (this module's original, post-hoc role);
//! * **Allocation** — [`crate::allocation::energy`] clips `(τ, d)` to
//!   the per-learner budget frontier `E_k ≤ E_k^max` via
//!   [`crate::costmodel::EnergyCoeffs`] (the forecast twin of this
//!   model — same formula, quadratic-coefficient form);
//! * **Simulation** — [`crate::config::EnergyConfig`] gives devices
//!   batteries that this model drains, so depletion drives correlated
//!   churn through the event engine.

use crate::allocation::Allocation;
use crate::config::Scenario;

/// Energy model constants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyParams {
    /// Effective switched capacitance κ (J · s²/cycles³ scale).
    pub kappa: f64,
    /// Receive power as a fraction of TX power: the downlink leg
    /// `t_k^S` is billed at `rx_power_ratio · P_k`. The default 1.0
    /// folds RX in at TX power — conservative for Wi-Fi (RX ≈ TX power
    /// class) and bit-identical to the historical audit behavior.
    pub rx_power_ratio: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self { kappa: 1e-28, rx_power_ratio: 1.0 }
    }
}

/// Per-learner energy breakdown for one global cycle (joules).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    /// Local-training energy `κ·f²·C_m·τ·d` (E^comp).
    pub compute_j: f64,
    /// Radio energy: uplink at `P_k`, downlink at `rx_power_ratio·P_k`.
    pub tx_j: f64,
}

impl EnergyReport {
    /// Total round energy `E_k = E_k^comp + E_k^tx` (2012.00143 §II).
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.tx_j
    }
}

/// Energy of every learner under an allocation. The downlink (receive)
/// leg is billed at `rx_power_ratio · P_k`; at the default ratio 1.0
/// the correction term is exactly zero and the report is bit-identical
/// to the historical fold-RX-in-at-TX-power audit.
pub fn audit(scenario: &Scenario, alloc: &Allocation, params: &EnergyParams) -> Vec<EnergyReport> {
    let task = &scenario.config.task;
    let data_term = match scenario.config.data_scenario {
        crate::costmodel::DataScenario::TaskParallelization => {
            (task.features * task.data_precision_bits) as f64
        }
        crate::costmodel::DataScenario::DistributedDataset => 0.0,
    };
    scenario
        .devices
        .iter()
        .zip(scenario.links.iter().zip(&scenario.costs))
        .zip(alloc.tau.iter().zip(&alloc.d))
        .map(|((dev, (link, cost)), (&tau, &d))| {
            let cycles = task.compute_cycles_per_sample * tau as f64 * d as f64;
            let compute_j = params.kappa * dev.cpu_hz * dev.cpu_hz * cycles;
            // comm time = C¹·d + C⁰ (eq. 1 + eq. 3 combined)
            let t_comm = cost.c1 * d as f64 + cost.c0;
            // downlink share of that time (t_k^S: batch data + one
            // model copy), re-priced by the RX/TX ratio
            let t_down = ((data_term
                + (task.model_precision_bits * task.model_size_per_sample) as f64)
                * d as f64
                + task.model_bits() as f64)
                / link.rate_bps;
            let tx_j = dev.tx_power_w * t_comm
                + (params.rx_power_ratio - 1.0) * dev.tx_power_w * t_down;
            EnergyReport { compute_j, tx_j }
        })
        .collect()
}

/// Jain's fairness index over per-learner total energy: 1 = perfectly
/// even drain, 1/K = one node pays for everything. Battery fairness is
/// the practical concern ETA-style equal batching ignores.
pub fn jain_fairness(reports: &[EnergyReport]) -> f64 {
    let k = reports.len();
    if k == 0 {
        return 1.0;
    }
    let sum: f64 = reports.iter().map(|r| r.total_j()).sum();
    let sum_sq: f64 = reports.iter().map(|r| r.total_j().powi(2)).sum();
    if sum_sq == 0.0 {
        return 1.0;
    }
    sum * sum / (k as f64 * sum_sq)
}

/// Fleet-level summary.
#[derive(Debug, Clone, Copy)]
pub struct EnergySummary {
    /// Fleet-wide round energy (sum over learners).
    pub total_j: f64,
    /// Worst single learner's round energy.
    pub max_j: f64,
    /// Jain's fairness index over per-learner round energies (1 = all
    /// equal, 1/K = one learner burns everything).
    pub fairness: f64,
}

/// Reduce per-learner reports to fleet totals, the per-learner peak,
/// and Jain's fairness index over round energies.
pub fn summarize(reports: &[EnergyReport]) -> EnergySummary {
    EnergySummary {
        total_j: reports.iter().map(|r| r.total_j()).sum(),
        max_j: reports.iter().map(|r| r.total_j()).fold(0.0, f64::max),
        fairness: jain_fairness(reports),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::{make_allocator, AllocatorKind};
    use crate::config::ScenarioConfig;

    fn scenario() -> Scenario {
        ScenarioConfig::paper_default().with_learners(10).build()
    }

    fn alloc(s: &Scenario, kind: AllocatorKind) -> Allocation {
        make_allocator(kind)
            .allocate(&s.costs, s.t_cycle(), s.total_samples(), &s.bounds)
            .unwrap()
    }

    #[test]
    fn energy_is_positive_and_bounded() {
        let s = scenario();
        let a = alloc(&s, AllocatorKind::Sai);
        let reports = audit(&s, &a, &EnergyParams::default());
        assert_eq!(reports.len(), 10);
        for r in &reports {
            assert!(r.compute_j > 0.0, "learning nodes burn compute energy");
            assert!(r.tx_j > 0.0);
            // a phone-class device over a 15 s cycle stays under ~100 J
            assert!(r.total_j() < 100.0, "implausible energy {}", r.total_j());
        }
    }

    #[test]
    fn compute_energy_scales_with_work() {
        let s = scenario();
        let mut a = alloc(&s, AllocatorKind::Sai);
        let base = audit(&s, &a, &EnergyParams::default());
        // double the first learner's epochs -> its compute energy doubles
        a.tau[0] *= 2;
        let doubled = audit(&s, &a, &EnergyParams::default());
        let ratio = doubled[0].compute_j / base[0].compute_j;
        assert!((ratio - 2.0).abs() < 1e-9, "ratio {ratio}");
        assert_eq!(doubled[0].tx_j, base[0].tx_j);
    }

    #[test]
    fn fairness_index_bounds() {
        let even = vec![EnergyReport { compute_j: 1.0, tx_j: 0.0 }; 8];
        assert!((jain_fairness(&even) - 1.0).abs() < 1e-12);
        let mut skewed = vec![EnergyReport { compute_j: 0.0, tx_j: 0.0 }; 8];
        skewed[0].compute_j = 5.0;
        assert!((jain_fairness(&skewed) - 0.125).abs() < 1e-12);
        assert_eq!(jain_fairness(&[]), 1.0);
    }

    #[test]
    fn optimized_allocation_is_fairer_than_eta_on_energy() {
        // ETA gives slow devices the same batch as fast ones, so fast
        // laptops burning f² on more epochs dominate the drain; the
        // optimized allocation moves data toward capability, evening
        // out *time* (t_k = T) and hence roughly the duty cycle.
        let s = scenario();
        let sai = audit(&s, &alloc(&s, AllocatorKind::Sai), &EnergyParams::default());
        let eta = audit(&s, &alloc(&s, AllocatorKind::Eta), &EnergyParams::default());
        let f_sai = jain_fairness(&sai);
        let f_eta = jain_fairness(&eta);
        // not a theorem, but holds comfortably on the paper scenario
        assert!(
            f_sai >= f_eta - 0.05,
            "sai fairness {f_sai} vs eta {f_eta}"
        );
    }

    #[test]
    fn rx_power_ratio_reprices_only_the_downlink() {
        let s = scenario();
        let a = alloc(&s, AllocatorKind::Sai);
        let base = audit(&s, &a, &EnergyParams::default());
        let half = audit(
            &s,
            &a,
            &EnergyParams { rx_power_ratio: 0.5, ..EnergyParams::default() },
        );
        for (b, h) in base.iter().zip(&half) {
            assert_eq!(h.compute_j, b.compute_j, "compute is radio-independent");
            assert!(h.tx_j < b.tx_j && h.tx_j > 0.0, "cheaper RX lowers radio energy");
        }
        // an explicit ratio of 1.0 is bit-identical to the default —
        // the Wi-Fi conservatism fold-in is preserved, not approximated
        let one = audit(
            &s,
            &a,
            &EnergyParams { rx_power_ratio: 1.0, ..EnergyParams::default() },
        );
        for (b, o) in base.iter().zip(&one) {
            assert_eq!(o.tx_j, b.tx_j);
        }
    }

    #[test]
    fn summary_aggregates() {
        let reports = vec![
            EnergyReport { compute_j: 1.0, tx_j: 1.0 },
            EnergyReport { compute_j: 3.0, tx_j: 0.0 },
        ];
        let s = summarize(&reports);
        assert!((s.total_j - 5.0).abs() < 1e-12);
        assert!((s.max_j - 3.0).abs() < 1e-12);
        assert!(s.fairness > 0.5 && s.fairness < 1.0);
    }
}
