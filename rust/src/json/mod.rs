//! Minimal JSON substrate (this image's cargo registry has no serde_json
//! — see Cargo.toml). Full RFC-8259 value model: parser, pretty writer,
//! typed accessors. Used for the artifact manifest and scenario configs.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// BTreeMap keeps key order deterministic for stable file diffs.
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — builder use only).
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access with a useful error.
    pub fn field(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing JSON field '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > u64::MAX as f64 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Convenience: field -> f64.
    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.field(key)?.as_f64().context(format!("field '{key}'"))
    }

    pub fn u64_field(&self, key: &str) -> Result<u64> {
        self.field(key)?.as_u64().context(format!("field '{key}'"))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.field(key)?.as_usize().context(format!("field '{key}'"))
    }

    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.field(key)?.as_str().context(format!("field '{key}'"))
    }

    /// Pretty-printed JSON text.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Compact JSON text.
    pub fn compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, false); // arrays inline
                }
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    out.push(' ');
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
        Ok(Value::Obj(m))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
        Ok(Value::Arr(a))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => break,
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        // surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()? as char;
                                low = low * 16
                                    + c.to_digit(16)
                                        .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            }
                            let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(code)
                        };
                        s.push(ch.ok_or_else(|| anyhow!("invalid codepoint"))?);
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            bail!("truncated UTF-8");
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .context("invalid UTF-8 in string")?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
        Ok(s)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .with_context(|| format!("invalid number '{text}'"))?;
        Ok(Value::Num(n))
    }
}

// ----------------------------------------------------------------------
// Bit-exact float encoding (checkpoint substrate)
// ----------------------------------------------------------------------
//
// Plain JSON numbers round-trip finite f64s exactly (Rust prints the
// shortest digit string that parses back to the same bits), but they
// cannot carry NaN/∞ and re-parsing f32 training state through f64
// text is needlessly fragile. Checkpoints therefore store floats as
// fixed-width lowercase hex of the IEEE-754 bit pattern: 16 digits for
// f64, 8 for f32, and whole `f32` tensors as one concatenated string.

/// Encode an `f64` as the 16-hex-digit big-endian form of `to_bits`.
pub fn f64_to_hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Decode [`f64_to_hex`] output; bit-exact, including NaN payloads.
pub fn f64_from_hex(s: &str) -> Result<f64> {
    if s.len() != 16 {
        bail!("f64 hex must be 16 digits, got '{s}'");
    }
    let bits = u64::from_str_radix(s, 16).with_context(|| format!("bad f64 hex '{s}'"))?;
    Ok(f64::from_bits(bits))
}

/// Encode an `f32` as the 8-hex-digit big-endian form of `to_bits`.
pub fn f32_to_hex(v: f32) -> String {
    format!("{:08x}", v.to_bits())
}

/// Decode [`f32_to_hex`] output; bit-exact, including NaN payloads.
pub fn f32_from_hex(s: &str) -> Result<f32> {
    if s.len() != 8 {
        bail!("f32 hex must be 8 digits, got '{s}'");
    }
    let bits = u32::from_str_radix(s, 16).with_context(|| format!("bad f32 hex '{s}'"))?;
    Ok(f32::from_bits(bits))
}

/// Encode an `f32` tensor as one concatenated 8-hex-per-element string
/// (a `ParamSet` layer serializes to a single compact JSON string).
pub fn tensor_to_hex(t: &[f32]) -> String {
    let mut s = String::with_capacity(t.len() * 8);
    for &v in t {
        let _ = fmt::Write::write_fmt(&mut s, format_args!("{:08x}", v.to_bits()));
    }
    s
}

/// Decode [`tensor_to_hex`] output back into the exact bit pattern.
pub fn tensor_from_hex(s: &str) -> Result<Vec<f32>> {
    if s.len() % 8 != 0 {
        bail!("tensor hex length {} is not a multiple of 8", s.len());
    }
    if !s.is_ascii() {
        bail!("tensor hex must be ASCII");
    }
    s.as_bytes()
        .chunks(8)
        .map(|c| f32_from_hex(std::str::from_utf8(c).expect("ascii checked above")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.field("c").unwrap().as_str().unwrap(), "x");
        let arr = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].field("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn round_trips_pretty_and_compact() {
        let mut v = Value::obj();
        v.set("k", 10u64)
            .set("t", 7.5)
            .set("name", "paper")
            .set("list", vec![1u64, 2, 3])
            .set("flag", true);
        for text in [v.pretty(), v.compact()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, v, "text: {text}");
        }
    }

    #[test]
    fn escapes_round_trip() {
        let v = Value::Str("a\"b\\c\nd\te\u{0007}".into());
        let text = v.compact();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_round_trips() {
        let v = parse(r#""é€ x""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é€ x");
        // raw UTF-8 too
        let v2 = parse("\"é€\"").unwrap();
        assert_eq!(v2.as_str().unwrap(), "é€");
        // surrogate pair
        let v3 = parse(r#""😀""#).unwrap();
        assert_eq!(v3.as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn integer_detection_in_writer() {
        assert_eq!(Value::Num(60000.0).compact(), "60000");
        assert_eq!(Value::Num(7.5).compact(), "7.5");
    }

    #[test]
    fn as_u64_guards() {
        assert!(Value::Num(-1.0).as_u64().is_err());
        assert!(Value::Num(1.5).as_u64().is_err());
        assert_eq!(Value::Num(42.0).as_u64().unwrap(), 42);
    }

    #[test]
    fn typed_field_errors_name_the_field() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        let err = v.str_field("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn float_hex_round_trips_bit_exactly() {
        for v in [0.0f64, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, f64::NEG_INFINITY, f64::NAN] {
            let back = f64_from_hex(&f64_to_hex(v)).unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "f64 {v}");
        }
        for v in [0.0f32, -0.0, 0.1, f32::MAX, f32::INFINITY, f32::NAN] {
            let back = f32_from_hex(&f32_to_hex(v)).unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "f32 {v}");
        }
    }

    #[test]
    fn tensor_hex_round_trips() {
        let t: Vec<f32> = (0..257).map(|i| (i as f32 - 100.5) * 0.3).collect();
        let s = tensor_to_hex(&t);
        assert_eq!(s.len(), t.len() * 8);
        let back = tensor_from_hex(&s).unwrap();
        assert_eq!(back.len(), t.len());
        for (a, b) in t.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(tensor_from_hex("abc").is_err());
        assert!(f64_from_hex("xyz").is_err());
        assert!(f32_from_hex("0123456z").is_err());
    }
}
