//! Exact integer allocator — the optimality yardstick.
//!
//! Not in the paper (which stops at relax-and-round, leaving the
//! max-constrained integer problem to future work); we add it because the
//! full-duration equality (7b) *forces* `d_k` once `τ_k` is known, which
//! collapses the IQCLP to a one-dimensional-per-learner structure:
//!
//! With integer batches and work-conserving epochs
//! `τ_k(d) = ⌊(T − C⁰_k − C¹_k d)/(C²_k d)⌋` (non-increasing in `d`), an
//! allocation with staleness `≤ z` and base `a` requires
//! `τ_k(d_k) ∈ [a, a+z]`, i.e. `d_k` in the integer interval
//!
//! ```text
//! lo_k(a, z) = max(d_l, d̄_k(a+z+1) + 1)      (τ_k ≤ a+z)
//! hi_k(a)    = min(d_u, d̄_k(a))              (τ_k ≥ a)
//! d̄_k(τ)    = ⌊(T − C⁰_k)/(C¹_k + C²_k τ)⌋   (max batch allowing τ epochs)
//! ```
//!
//! Feasibility of `(a, z)` is the interval test
//! `Σ lo_k ≤ d ≤ Σ hi_k`. Scanning `z = 0, 1, …` (outer) and all bases
//! `a` (inner) finds the *provably minimal* max-staleness; among bases
//! with minimal `z` we keep the assignment with the best average
//! staleness (eq. 13) as a tiebreak.

use anyhow::{anyhow, ensure, Result};

use crate::allocation::{common, Allocation, TaskAllocator};
use crate::costmodel::{Bounds, LearnerCost};

/// Options for [`ExactAllocator`].
#[derive(Debug, Clone, Copy)]
pub struct ExactOptions {
    /// Safety cap on the τ search space (guards tiny-`d_l` blowups).
    pub tau_cap: u64,
    /// `None`: minimize staleness first (the paper's objective 7a).
    /// `Some(z)`: treat `z` as an acceptable staleness *budget* and
    /// maximize learning work Σ τ_k d_k within it — the trade the
    /// paper's own (non-convex, SAI-repaired) solutions land on in
    /// Fig. 2, where max staleness hovers at ~1 rather than 0 and the
    /// extra epochs on fast nodes buy the §V-C accuracy gain over sync.
    pub staleness_budget: Option<u64>,
}

impl Default for ExactOptions {
    fn default() -> Self {
        Self { tau_cap: 100_000, staleness_budget: None }
    }
}

/// Exact integer window-search allocator.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExactAllocator {
    pub opts: ExactOptions,
}

impl ExactAllocator {
    /// Max integer batch that still permits `tau` epochs, clipped to the
    /// box; `None` if not even `d_l` fits.
    fn d_cap(cost: &LearnerCost, tau: u64, t_cycle: f64, d_hi: u64) -> Option<u64> {
        let cap = cost.d_max_int_for_tau(tau, t_cycle)?;
        Some(cap.min(d_hi))
    }

    /// Integer d interval for `τ_k(d) ∈ [a, a+z]`, or `None` if empty.
    fn d_interval(
        cost: &LearnerCost,
        a: u64,
        z: u64,
        t_cycle: f64,
        bounds: &Bounds,
    ) -> Option<(u64, u64)> {
        let hi = Self::d_cap(cost, a, t_cycle, bounds.d_hi)?;
        if hi < bounds.d_lo {
            return None;
        }
        let lo = match cost.d_max_int_for_tau(a + z + 1, t_cycle) {
            Some(cap) => cap.saturating_add(1).max(bounds.d_lo),
            // even d = 0 can't fit a+z+1 epochs -> any d keeps τ ≤ a+z
            None => bounds.d_lo,
        };
        (lo <= hi).then_some((lo, hi))
    }

    /// Try base `a` with staleness budget `z`; returns a feasible
    /// assignment (d at lo, residual filled greedily) if one exists.
    fn try_window(
        costs: &[LearnerCost],
        a: u64,
        z: u64,
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Option<Vec<u64>> {
        let k = costs.len();
        let mut lo = Vec::with_capacity(k);
        let mut hi = Vec::with_capacity(k);
        for c in costs {
            let (l, h) = Self::d_interval(c, a, z, t_cycle, bounds)?;
            lo.push(l);
            hi.push(h);
        }
        let sum_lo: u64 = lo.iter().sum();
        let sum_hi: u64 = hi.iter().sum();
        if !(sum_lo <= d_total && d_total <= sum_hi) {
            return None;
        }
        // fill from lo toward hi
        let mut d = lo;
        let mut rest = d_total - sum_lo;
        for i in 0..k {
            let take = rest.min(hi[i] - d[i]);
            d[i] += take;
            rest -= take;
            if rest == 0 {
                break;
            }
        }
        debug_assert_eq!(rest, 0);
        Some(d)
    }
}

impl TaskAllocator for ExactAllocator {
    fn allocate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Allocation> {
        let k = costs.len();
        ensure!(k > 0, "no learners");
        ensure!(
            bounds.d_lo * k as u64 <= d_total && d_total <= bounds.d_hi * k as u64,
            "bounds make Σd = {d_total} unreachable for K = {k}"
        );

        // Highest achievable τ over the fleet (at the smallest batch).
        let tau_top = costs
            .iter()
            .filter_map(|c| c.tau_max_int(bounds.d_lo, t_cycle))
            .max()
            .ok_or_else(|| anyhow!("no learner can exchange the model within T = {t_cycle}s"))?
            .min(self.opts.tau_cap);

        let z_iter: Vec<u64> = match self.opts.staleness_budget {
            // budget mode: only windows up to the budget, best work wins
            Some(budget) => vec![budget.min(tau_top)],
            None => (0..=tau_top).collect(),
        };
        for z in z_iter {
            // Among all bases with the minimal staleness budget z, pick
            // the one doing the most learning work Σ τ_k d_k (the
            // integer realization of the full-duration equality 7b —
            // accuracy in MEL grows with updates, §III), tie-broken by
            // the lower average staleness (eq. 13).
            let mut best: Option<(u128, f64, Vec<u64>)> = None;
            for a in 0..=(tau_top - z) {
                if let Some(d) = Self::try_window(costs, a, z, t_cycle, d_total, bounds) {
                    let tau = common::work_conserving_tau(costs, &d, t_cycle);
                    let alloc = Allocation { tau, d };
                    debug_assert!(alloc.max_staleness() <= z);
                    let work: u128 = alloc
                        .tau
                        .iter()
                        .zip(&alloc.d)
                        .map(|(&t, &di)| t as u128 * di as u128)
                        .sum();
                    let avg = alloc.avg_staleness();
                    let better = match &best {
                        None => true,
                        Some((bw, ba, _)) => work > *bw || (work == *bw && avg < *ba),
                    };
                    if better {
                        best = Some((work, avg, alloc.d));
                    }
                }
            }
            if let Some((_, _, d)) = best {
                let tau = common::work_conserving_tau(costs, &d, t_cycle);
                return Ok(Allocation { tau, d });
            }
        }
        Err(anyhow!("no feasible integer allocation up to z = {tau_top}"))
    }

    fn name(&self) -> &'static str {
        "exact"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::eta::EtaAllocator;

    fn het_costs(k: usize) -> Vec<LearnerCost> {
        (0..k)
            .map(|i| {
                let c2 = if i % 2 == 0 { 4.5e-4 } else { 1.6e-3 };
                LearnerCost::new(c2, 1.1e-4 + 1e-5 * (i % 4) as f64, 0.3 + 0.04 * (i % 3) as f64)
            })
            .collect()
    }

    #[test]
    fn exact_is_feasible_and_work_conserving() {
        let costs = het_costs(10);
        let d_total = 30_000;
        let bounds = Bounds::proportional(d_total, 10, 0.2, 2.5);
        let a = ExactAllocator::default()
            .allocate(&costs, 7.5, d_total, &bounds)
            .unwrap();
        a.validate(&costs, 7.5, d_total, &bounds).unwrap();
        assert!(a.is_work_conserving(&costs, 7.5));
    }

    #[test]
    fn exact_beats_or_matches_eta() {
        for k in [4usize, 8, 10, 14] {
            let costs = het_costs(k);
            let d_total = 3_000 * k as u64;
            let bounds = Bounds::proportional(d_total, k, 0.2, 2.5);
            for t_cycle in [7.5, 15.0] {
                let ex = ExactAllocator::default()
                    .allocate(&costs, t_cycle, d_total, &bounds)
                    .unwrap();
                let eta = EtaAllocator
                    .allocate(&costs, t_cycle, d_total, &bounds)
                    .unwrap();
                assert!(
                    ex.max_staleness() <= eta.max_staleness(),
                    "k={k} T={t_cycle}: exact {} > eta {}",
                    ex.max_staleness(),
                    eta.max_staleness()
                );
            }
        }
    }

    #[test]
    fn exact_gets_low_staleness_on_heterogeneous_fleet() {
        let costs = het_costs(20);
        let d_total = 60_000;
        let bounds = Bounds::proportional(d_total, 20, 0.2, 2.5);
        let a = ExactAllocator::default()
            .allocate(&costs, 7.5, d_total, &bounds)
            .unwrap();
        // the paper's headline: optimized allocation keeps max staleness ~1
        assert!(a.max_staleness() <= 1, "staleness {} tau={:?}", a.max_staleness(), a.tau);
    }

    #[test]
    fn exact_is_optimal_vs_bruteforce_small() {
        // K = 2, tiny universe: brute force all (d_0, d_1) splits
        let costs = het_costs(2);
        let d_total = 600u64;
        let bounds = Bounds::new(100, 500);
        let t_cycle = 2.0;
        let mut brute_best = u64::MAX;
        for d0 in bounds.d_lo..=bounds.d_hi.min(d_total - bounds.d_lo) {
            let d1 = d_total - d0;
            if !bounds.contains(d1) {
                continue;
            }
            let tau = common::work_conserving_tau(&costs, &[d0, d1], t_cycle);
            let s = tau.iter().max().unwrap() - tau.iter().min().unwrap();
            brute_best = brute_best.min(s);
        }
        let a = ExactAllocator::default()
            .allocate(&costs, t_cycle, d_total, &bounds)
            .unwrap();
        assert_eq!(a.max_staleness(), brute_best);
    }

    #[test]
    fn single_learner_gets_everything() {
        let costs = het_costs(1);
        let bounds = Bounds::new(1, 10_000);
        let a = ExactAllocator::default()
            .allocate(&costs, 15.0, 5_000, &bounds)
            .unwrap();
        assert_eq!(a.d, vec![5_000]);
        assert_eq!(a.max_staleness(), 0);
    }

    #[test]
    fn errors_when_bounds_exclude_total() {
        let costs = het_costs(3);
        let bounds = Bounds::new(100, 200);
        assert!(ExactAllocator::default()
            .allocate(&costs, 15.0, 10_000, &bounds)
            .is_err());
    }
}
