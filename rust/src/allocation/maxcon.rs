//! Max-constrained staleness minimization — the paper's stated future
//! work (§III: "In the future work, we will look into finding an
//! efficient solution for the max-constrained problem").
//!
//! Staleness-aware async-SGD [10] operates with a *preset maximum* of
//! local updates: the aggregator waits until at least one learner has
//! performed `τ_max` epochs. The max-constrained allocation problem is
//! therefore: minimize `max |τ_k − τ_l|` subject to (7b)–(7f) **and**
//! `max_k τ_k = τ_max`.
//!
//! The reduced-space structure solves this too: it is exactly the
//! window search of [`super::exact`] with the window *anchored at the
//! top* — `[τ_max − z, τ_max]` — plus the extra requirement that at
//! least one learner actually sits at `τ_max`. Scanning `z` upward
//! yields the provably minimal staleness for the preset.

use anyhow::{anyhow, ensure, Result};

use crate::allocation::{common, Allocation, TaskAllocator};
use crate::costmodel::{Bounds, LearnerCost};

/// Exact allocator for the max-constrained problem.
#[derive(Debug, Clone, Copy)]
pub struct MaxConstrainedAllocator {
    /// The preset maximum updates `τ_max` (the [10]-style front).
    pub tau_max: u64,
}

impl MaxConstrainedAllocator {
    pub fn new(tau_max: u64) -> Self {
        assert!(tau_max >= 1, "τ_max must be at least one update");
        Self { tau_max }
    }

    /// Integer d range on learner `k` for `τ_k(d) ∈ [lo_tau, hi_tau]`
    /// (reuses the exact allocator's interval algebra).
    fn d_interval(
        cost: &LearnerCost,
        lo_tau: u64,
        hi_tau: u64,
        t_cycle: f64,
        bounds: &Bounds,
    ) -> Option<(u64, u64)> {
        // τ ≥ lo_tau  ⟺  d ≤ d̄(lo_tau)
        let hi = cost
            .d_max_int_for_tau(lo_tau, t_cycle)?
            .min(bounds.d_hi);
        if hi < bounds.d_lo {
            return None;
        }
        // τ ≤ hi_tau  ⟺  d ≥ d̄(hi_tau + 1) + 1
        let lo = match cost.d_max_int_for_tau(hi_tau + 1, t_cycle) {
            Some(cap) => cap.saturating_add(1).max(bounds.d_lo),
            None => bounds.d_lo,
        };
        (lo <= hi).then_some((lo, hi))
    }

    /// d range forcing `τ_k(d) = tau` exactly.
    fn d_interval_exact_tau(
        cost: &LearnerCost,
        tau: u64,
        t_cycle: f64,
        bounds: &Bounds,
    ) -> Option<(u64, u64)> {
        Self::d_interval(cost, tau, tau, t_cycle, bounds)
    }
}

impl TaskAllocator for MaxConstrainedAllocator {
    fn allocate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Allocation> {
        let k = costs.len();
        ensure!(k > 0, "no learners");
        let tau_max = self.tau_max;

        // learners that CAN hit τ_max within the box
        let anchors: Vec<usize> = (0..k)
            .filter(|&i| {
                Self::d_interval_exact_tau(&costs[i], tau_max, t_cycle, bounds).is_some()
            })
            .collect();
        ensure!(
            !anchors.is_empty(),
            "no learner can reach τ_max = {tau_max} within T = {t_cycle}s and the d-bounds"
        );

        for z in 0..=tau_max {
            let lo_tau = tau_max - z;
            // every learner needs τ ∈ [lo_tau, tau_max]
            let intervals: Option<Vec<(u64, u64)>> = costs
                .iter()
                .map(|c| Self::d_interval(c, lo_tau, tau_max, t_cycle, bounds))
                .collect();
            let Some(intervals) = intervals else { continue };
            let sum_lo: u64 = intervals.iter().map(|&(l, _)| l).sum();
            let sum_hi: u64 = intervals.iter().map(|&(_, h)| h).sum();
            if !(sum_lo <= d_total && d_total <= sum_hi) {
                continue;
            }

            // anchor each candidate learner at τ_max in turn and check
            // the residual mass still fits the other intervals
            for &a in &anchors {
                let Some((al, ah)) =
                    Self::d_interval_exact_tau(&costs[a], tau_max, t_cycle, bounds)
                else {
                    continue;
                };
                let rest_lo: u64 = intervals
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != a)
                    .map(|(_, &(l, _))| l)
                    .sum();
                let rest_hi: u64 = intervals
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != a)
                    .map(|(_, &(_, h))| h)
                    .sum();
                // pick anchor batch: smallest that leaves a feasible rest
                let need_lo = d_total.saturating_sub(rest_hi).max(al);
                let need_hi = d_total.saturating_sub(rest_lo).min(ah);
                if need_lo > need_hi {
                    continue;
                }
                let anchor_d = need_lo;
                // fill the rest from lo toward hi
                let mut d: Vec<u64> = intervals.iter().map(|&(l, _)| l).collect();
                d[a] = anchor_d;
                let mut placed: u64 = d.iter().sum();
                for i in 0..k {
                    if i == a {
                        continue;
                    }
                    let take = (d_total - placed).min(intervals[i].1 - d[i]);
                    d[i] += take;
                    placed += take;
                    if placed == d_total {
                        break;
                    }
                }
                if placed != d_total {
                    continue;
                }
                let tau = common::work_conserving_tau(costs, &d, t_cycle);
                let alloc = Allocation { tau, d };
                debug_assert_eq!(*alloc.tau.iter().max().unwrap(), tau_max);
                debug_assert!(alloc.max_staleness() <= z);
                return Ok(alloc);
            }
        }
        Err(anyhow!(
            "max-constrained problem infeasible for τ_max = {tau_max}"
        ))
    }

    fn name(&self) -> &'static str {
        "maxcon"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::exact::ExactAllocator;
    use crate::config::ScenarioConfig;

    fn scenario(k: usize, t: f64) -> crate::config::Scenario {
        ScenarioConfig::paper_default()
            .with_learners(k)
            .with_cycle(t)
            .build()
    }

    #[test]
    fn front_learner_hits_tau_max_exactly() {
        // presets anchored on the unconstrained optimum's front are
        // always feasible (small τ_max can be genuinely infeasible:
        // fast nodes cannot be held below ~3 epochs within d ≤ d_u)
        let s = scenario(10, 15.0);
        let free = ExactAllocator::default()
            .allocate(&s.costs, 15.0, s.total_samples(), &s.bounds)
            .unwrap();
        let front = *free.tau.iter().max().unwrap();
        for tau_max in [front, front + 1] {
            let a = MaxConstrainedAllocator::new(tau_max)
                .allocate(&s.costs, 15.0, s.total_samples(), &s.bounds)
                .unwrap_or_else(|e| panic!("tau_max={tau_max}: {e}"));
            assert_eq!(*a.tau.iter().max().unwrap(), tau_max);
            a.validate(&s.costs, 15.0, s.total_samples(), &s.bounds)
                .unwrap();
            assert!(a.is_work_conserving(&s.costs, 15.0));
        }
    }

    #[test]
    fn staleness_is_minimal_for_the_preset() {
        // for an achievable τ_max near the unconstrained optimum the
        // staleness must match the unconstrained exact solution
        let s = scenario(12, 15.0);
        let free = ExactAllocator::default()
            .allocate(&s.costs, 15.0, s.total_samples(), &s.bounds)
            .unwrap();
        let tau_front = *free.tau.iter().max().unwrap();
        let anchored = MaxConstrainedAllocator::new(tau_front)
            .allocate(&s.costs, 15.0, s.total_samples(), &s.bounds)
            .unwrap();
        assert!(anchored.max_staleness() <= free.max_staleness() + 1);
    }

    #[test]
    fn unreachable_tau_max_errors() {
        let s = scenario(6, 7.5);
        assert!(MaxConstrainedAllocator::new(10_000)
            .allocate(&s.costs, 7.5, s.total_samples(), &s.bounds)
            .is_err());
    }

    #[test]
    fn higher_preset_forces_more_staleness() {
        // pushing the front far above what slow nodes can do must cost
        // staleness monotonically (weakly)
        let s = scenario(10, 15.0);
        let mut prev = 0u64;
        for tau_max in 1..=6u64 {
            if let Ok(a) = MaxConstrainedAllocator::new(tau_max).allocate(
                &s.costs,
                15.0,
                s.total_samples(),
                &s.bounds,
            ) {
                let stale = a.max_staleness();
                if tau_max >= 4 {
                    assert!(
                        stale >= prev || stale == 0,
                        "tau_max={tau_max}: staleness {stale} < prev {prev}"
                    );
                }
                prev = stale;
            }
        }
    }

    #[test]
    #[should_panic]
    fn zero_tau_max_rejected() {
        MaxConstrainedAllocator::new(0);
    }
}
