//! The paper's "optimizer-based/numerical" path: solve the relaxed
//! non-convex QCLP (8) with the augmented-Lagrangian solver, floor the
//! real solution back to integers, and repair with SAI steps — exactly
//! the §IV-A pipeline ("relaxing the integer constraints … solving the
//! relaxed problem, then flooring the obtained real results back into
//! integers", with "constraint checks and … suggest-and-improve steps"
//! when the non-convex solve lands infeasible).

use anyhow::{anyhow, ensure, Result};

use crate::allocation::sai::SaiAllocator;
use crate::allocation::{common, Allocation, TaskAllocator};
use crate::costmodel::{Bounds, LearnerCost};
use crate::solver::{solve_relaxed, RelaxedOptions};

/// Options for [`RelaxedAllocator`].
#[derive(Debug, Clone, Copy)]
pub struct RelaxedAllocatorOptions {
    pub solver: RelaxedOptions,
    /// Accept the numerical solution only below this constraint violation
    /// (relative); otherwise fall back to the SAI suggestion (§IV-A).
    pub max_violation: f64,
    /// Improve-loop round cap.
    pub improve_rounds: usize,
}

impl Default for RelaxedAllocatorOptions {
    fn default() -> Self {
        Self {
            solver: RelaxedOptions::default(),
            max_violation: 5e-2,
            improve_rounds: 400,
        }
    }
}

/// Relax → numerical solve → floor → SAI repair.
#[derive(Debug, Clone, Copy, Default)]
pub struct RelaxedAllocator {
    pub opts: RelaxedAllocatorOptions,
}

impl TaskAllocator for RelaxedAllocator {
    fn allocate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Allocation> {
        ensure!(!costs.is_empty(), "no learners");
        let sol = solve_relaxed(costs, t_cycle, d_total, bounds, &self.opts.solver);

        // §IV-A: "in some situations, the approach … resulted in
        // infeasible solutions. In that case, we performed constraint
        // checks and then used the initial solution to carry out
        // suggest-and-improve steps" — our constraint check is the
        // relative violation; the fallback suggestion is the SAI one.
        let d_real: Vec<f64> = if sol.feasibility <= self.opts.max_violation {
            sol.d
        } else {
            SaiAllocator::default()
                .suggest(costs, t_cycle, d_total, bounds)?
                .d
        };

        let mut d = common::integerize_batches(&d_real, d_total, bounds)
            .ok_or_else(|| anyhow!("bounds make Σd = {d_total} unreachable"))?;
        let alloc = common::improve_to_local_optimum(
            costs,
            &mut d,
            t_cycle,
            bounds,
            self.opts.improve_rounds,
        );
        debug_assert!(alloc.validate(costs, t_cycle, d_total, bounds).is_ok());
        Ok(alloc)
    }

    fn name(&self) -> &'static str {
        "relaxed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::eta::EtaAllocator;
    use crate::allocation::exact::ExactAllocator;

    fn het_costs(k: usize) -> Vec<LearnerCost> {
        (0..k)
            .map(|i| {
                let c2 = if i % 2 == 0 { 4.5e-4 } else { 1.6e-3 };
                LearnerCost::new(c2, 1.1e-4 + 1e-5 * (i % 4) as f64, 0.3 + 0.04 * (i % 3) as f64)
            })
            .collect()
    }

    #[test]
    fn relaxed_is_feasible_and_work_conserving() {
        let costs = het_costs(10);
        let d_total = 30_000u64;
        let bounds = Bounds::proportional(d_total, 10, 0.2, 2.5);
        let a = RelaxedAllocator::default()
            .allocate(&costs, 7.5, d_total, &bounds)
            .unwrap();
        a.validate(&costs, 7.5, d_total, &bounds).unwrap();
        assert!(a.is_work_conserving(&costs, 7.5));
    }

    #[test]
    fn relaxed_close_to_exact_optimum() {
        // the paper's observation: numerical and SAI curves nearly match;
        // both should land within 1 of the exact optimum here
        for k in [6usize, 10, 14] {
            let costs = het_costs(k);
            let d_total = 3_000 * k as u64;
            let bounds = Bounds::proportional(d_total, k, 0.2, 2.5);
            let rel = RelaxedAllocator::default()
                .allocate(&costs, 15.0, d_total, &bounds)
                .unwrap();
            let ex = ExactAllocator::default()
                .allocate(&costs, 15.0, d_total, &bounds)
                .unwrap();
            assert!(
                rel.max_staleness() <= ex.max_staleness() + 1,
                "k={k}: relaxed {} vs exact {}",
                rel.max_staleness(),
                ex.max_staleness()
            );
        }
    }

    #[test]
    fn relaxed_beats_eta() {
        let costs = het_costs(20);
        let d_total = 60_000u64;
        let bounds = Bounds::proportional(d_total, 20, 0.2, 2.5);
        let rel = RelaxedAllocator::default()
            .allocate(&costs, 7.5, d_total, &bounds)
            .unwrap();
        let eta = EtaAllocator.allocate(&costs, 7.5, d_total, &bounds).unwrap();
        assert!(rel.max_staleness() < eta.max_staleness());
    }
}
