//! Energy-budget-constrained allocation — the authors' sequel
//! (arXiv:2012.00143) grafted onto the paper's suggest-and-improve
//! structure.
//!
//! The deadline pipeline ends with a feasible integer point on the
//! `t_k ≤ T` manifold; this module adds the per-learner budget
//! `E_k^comp + E_k^tx ≤ E_k^max` as a second frontier, handled the same
//! way the deadline is: take the unconstrained *suggestion* (any base
//! [`TaskAllocator`]), **clip** each over-budget learner's `(τ_k, d_k)`
//! onto the energy-feasible frontier ([`EnergyCoeffs::tau_max_energy`],
//! the energy twin of [`LearnerCost::tau_max_int`]), then run a
//! `Σ d_k = D` **repair** sweep that hands the freed samples to
//! learners with both deadline *and* energy headroom.
//!
//! Two invariants drive the tests (`rust/tests/energy_path.rs`):
//!
//! * **budget-∞ oracle** — when every budget is infinite the base
//!   allocator's result is returned *verbatim* (the same `Allocation`
//!   value, bit for bit), so the unconstrained solver remains the
//!   differential oracle;
//! * **two-frontier feasibility** — finite budgets yield allocations
//!   satisfying the deadline (7b, as `≤ T`), the box (7f), and
//!   `E_k(τ_k, d_k) ≤ E_k^max` for every learner, with `Σ d_k = D`
//!   whenever the energy frontier leaves room ([`AllocationOutcome::
//!   shortfall`] reports the samples nobody could afford otherwise).

use anyhow::{ensure, Result};

use crate::allocation::{Allocation, TaskAllocator};
use crate::costmodel::{Bounds, EnergyCoeffs, LearnerCost};

/// Result of an energy-constrained solve: the allocation plus a typed
/// account of where the budget bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllocationOutcome {
    /// The (possibly clipped) assignment.
    pub alloc: Allocation,
    /// `energy_clamped[k]` — learner `k`'s suggested `(τ, d)` violated
    /// its budget and was clipped onto the energy frontier.
    pub energy_clamped: Vec<bool>,
    /// Samples of `D` that could not be placed because every learner
    /// with deadline headroom was already at its energy frontier
    /// (0 in the common case; `Σ d_k = D − shortfall`).
    pub shortfall: u64,
}

impl AllocationOutcome {
    /// Number of learners whose assignment was energy-clipped.
    pub fn clamped_count(&self) -> usize {
        self.energy_clamped.iter().filter(|&&c| c).count()
    }
}

/// `true` iff no budget can ever bind (all `+∞`) — the unconstrained
/// fast path.
pub fn budgets_unbounded(budgets: &[f64]) -> bool {
    budgets.iter().all(|&b| b == f64::INFINITY)
}

/// Solve `(τ, d)` under both the deadline and per-learner energy
/// budgets, suggest-and-improve style.
///
/// `coeffs[k]`/`budgets[k]` give learner `k`'s energy forecast and
/// budget `E_k^max` in joules (`f64::INFINITY` = unconstrained). With
/// every budget infinite, the base allocator's result is returned
/// verbatim — byte-identical to calling it directly.
pub fn allocate_energy_constrained(
    base: &(dyn TaskAllocator + Send + Sync),
    costs: &[LearnerCost],
    coeffs: &[EnergyCoeffs],
    budgets: &[f64],
    t_cycle: f64,
    d_total: u64,
    bounds: &Bounds,
) -> Result<AllocationOutcome> {
    let k = costs.len();
    ensure!(
        coeffs.len() == k && budgets.len() == k,
        "energy arity mismatch: costs={k} coeffs={} budgets={}",
        coeffs.len(),
        budgets.len()
    );
    ensure!(
        budgets.iter().all(|b| !b.is_nan() && *b > 0.0),
        "energy budgets must be positive (or +inf for unconstrained)"
    );
    let alloc = base.allocate(costs, t_cycle, d_total, bounds)?;
    if budgets_unbounded(budgets) {
        // the differential-oracle contract: no arithmetic touches the
        // unconstrained result, it is passed through as-is
        return Ok(AllocationOutcome {
            energy_clamped: vec![false; k],
            shortfall: 0,
            alloc,
        });
    }

    let mut tau = alloc.tau;
    let mut d = alloc.d;
    let mut clamped = vec![false; k];

    // --- clip: pull every over-budget learner onto the energy frontier
    // (before the Σd = D repair, so freed samples are redistributable)
    for i in 0..k {
        let e_max = budgets[i];
        if coeffs[i].energy(tau[i] as f64, d[i] as f64) <= e_max {
            continue; // suggestion already affordable
        }
        clamped[i] = true;
        match coeffs[i].tau_max_energy(d[i], e_max) {
            Some(te) if te >= 1 => {
                // fewer epochs at the suggested batch: deadline slack
                // only grows (t is increasing in τ)
                tau[i] = tau[i].min(te);
            }
            _ => {
                // even one epoch (or the bare exchange) busts the
                // budget at this batch — idle the learner (the paper's
                // τ = 0 infeasibility marker) and shrink its batch to
                // the box floor so the repair can re-place the samples
                tau[i] = 0;
                d[i] = bounds.d_lo;
                if coeffs[i].energy(0.0, d[i] as f64) > e_max {
                    // it cannot even hold the floor batch affordably;
                    // τ = 0 means no round runs, so no energy is spent
                    // — keep the floor batch as its share of the box
                }
            }
        }
    }

    // --- repair: restore Σ d_k = D by handing the freed samples to
    // learners with headroom on *both* frontiers, in index order
    // (deterministic; the same order integerize_batches sweeps)
    let placed: u64 = d.iter().sum();
    let mut deficit = d_total.saturating_sub(placed);
    if deficit > 0 {
        for i in 0..k {
            if deficit == 0 {
                break;
            }
            if tau[i] == 0 {
                continue; // idled learners take no extra work
            }
            // headroom: box ceiling ∧ deadline frontier ∧ energy frontier
            let cap_box = bounds.d_hi;
            let cap_time = costs[i].d_max_int_for_tau(tau[i], t_cycle).unwrap_or(0);
            let cap_energy = coeffs[i]
                .d_max_energy_at_tau(tau[i], budgets[i])
                .unwrap_or(0);
            let cap = cap_box.min(cap_time).min(cap_energy);
            if cap > d[i] {
                let take = (cap - d[i]).min(deficit);
                d[i] += take;
                deficit -= take;
            }
        }
    }

    Ok(AllocationOutcome {
        alloc: Allocation { tau, d },
        energy_clamped: clamped,
        shortfall: deficit,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::{make_allocator, AllocatorKind};

    fn het_costs(k: usize) -> Vec<LearnerCost> {
        (0..k)
            .map(|i| {
                let c2 = if i % 2 == 0 { 4.5e-4 } else { 1.6e-3 };
                LearnerCost::new(c2, 1.1e-4 + 1e-5 * (i % 4) as f64, 0.3 + 0.04 * (i % 3) as f64)
            })
            .collect()
    }

    fn het_coeffs(k: usize) -> Vec<EnergyCoeffs> {
        (0..k)
            .map(|i| {
                let e2 = if i % 2 == 0 { 4e-4 } else { 1e-4 };
                EnergyCoeffs::new(e2, 2e-5, 0.06)
            })
            .collect()
    }

    #[test]
    fn infinite_budgets_return_the_base_allocation_verbatim() {
        let k = 10;
        let costs = het_costs(k);
        let coeffs = het_coeffs(k);
        let d_total = 30_000u64;
        let bounds = Bounds::proportional(d_total, k, 0.2, 2.5);
        let base = make_allocator(AllocatorKind::Sai);
        let oracle = base.allocate(&costs, 7.5, d_total, &bounds).unwrap();
        let out = allocate_energy_constrained(
            base.as_ref(),
            &costs,
            &coeffs,
            &vec![f64::INFINITY; k],
            7.5,
            d_total,
            &bounds,
        )
        .unwrap();
        assert_eq!(out.alloc, oracle, "budget-∞ must be the oracle, bit for bit");
        assert_eq!(out.clamped_count(), 0);
        assert_eq!(out.shortfall, 0);
    }

    #[test]
    fn tight_budgets_clamp_and_stay_on_both_frontiers() {
        let k = 10;
        let costs = het_costs(k);
        let coeffs = het_coeffs(k);
        let t_cycle = 7.5;
        let d_total = 30_000u64;
        let bounds = Bounds::proportional(d_total, k, 0.2, 2.5);
        let base = make_allocator(AllocatorKind::Sai);
        // budget chosen to bite the fast (high-e2) learners only
        let budgets: Vec<f64> =
            (0..k).map(|i| if i % 2 == 0 { 6.0 } else { f64::INFINITY }).collect();
        let out = allocate_energy_constrained(
            base.as_ref(), &costs, &coeffs, &budgets, t_cycle, d_total, &bounds,
        )
        .unwrap();
        assert!(out.clamped_count() > 0, "budget never bit: raise e2 or lower it");
        for i in 0..k {
            let (tau, d) = (out.alloc.tau[i], out.alloc.d[i]);
            assert!(bounds.contains(d), "d[{i}] = {d} outside the box");
            let t = costs[i].time(tau as f64, d as f64);
            assert!(t <= t_cycle * (1.0 + 1e-9), "learner {i} misses the deadline");
            if tau > 0 {
                let e = coeffs[i].energy(tau as f64, d as f64);
                assert!(
                    e <= budgets[i] * (1.0 + 1e-9),
                    "learner {i}: E = {e} over budget {}",
                    budgets[i]
                );
            }
        }
        assert_eq!(
            out.alloc.d.iter().sum::<u64>() + out.shortfall,
            d_total,
            "repair must account for every sample"
        );
    }

    #[test]
    fn starvation_budget_idles_learners_not_the_solve() {
        let k = 6;
        let costs = het_costs(k);
        let coeffs = het_coeffs(k);
        let d_total = 18_000u64;
        let bounds = Bounds::proportional(d_total, k, 0.2, 2.5);
        let base = make_allocator(AllocatorKind::Eta);
        // below every learner's bare exchange energy (e0 = 0.06)
        let budgets = vec![0.01f64; k];
        let out = allocate_energy_constrained(
            base.as_ref(), &costs, &coeffs, &budgets, 7.5, d_total, &bounds,
        )
        .unwrap();
        assert!(out.alloc.tau.iter().all(|&t| t == 0), "nobody can afford a round");
        assert_eq!(out.clamped_count(), k);
        assert!(out.shortfall > 0, "idled fleet cannot place all of D");
    }

    #[test]
    fn arity_and_sign_errors_are_typed() {
        let costs = het_costs(4);
        let coeffs = het_coeffs(3);
        let bounds = Bounds::new(10, 10_000);
        let base = make_allocator(AllocatorKind::Eta);
        assert!(allocate_energy_constrained(
            base.as_ref(), &costs, &coeffs, &[1.0; 4], 7.5, 4000, &bounds,
        )
        .is_err());
        let coeffs = het_coeffs(4);
        assert!(allocate_energy_constrained(
            base.as_ref(), &costs, &coeffs, &[1.0, -2.0, 1.0, 1.0], 7.5, 4000, &bounds,
        )
        .is_err());
    }
}
