//! Task allocation — the paper's contribution and its baselines.
//!
//! Five allocators, all producing an [`Allocation`] of per-learner
//! `(τ_k, d_k)`:
//!
//! | kind | paper role | module |
//! |---|---|---|
//! | [`AllocatorKind::Relaxed`] | "optimizer-based/numerical" curve: relaxed problem (8) via augmented Lagrangian, floored, SAI-repaired | [`relaxed`] |
//! | [`AllocatorKind::Sai`] | "SAI" curve: KKT-structured suggest + suggest-and-improve (§IV) | [`sai`] |
//! | [`AllocatorKind::Exact`] | optimality yardstick: exact integer window search over the reduced space (DESIGN.md) | [`exact`] |
//! | [`AllocatorKind::Eta`] | asynchronous Equal Task Allocation baseline [10] | [`eta`] |
//! | [`AllocatorKind::Sync`] | synchronous MEL of [9]: common τ, `t_k ≤ T` | [`sync`] |
//!
//! Orthogonal to the kind, [`allocate_energy_constrained`] wraps any of
//! the five with per-learner energy budgets `E_k ≤ E_k^max` (the
//! authors' sequel, arXiv:2012.00143) and reports the clipping in a
//! typed [`AllocationOutcome`]. See [`energy`].

pub mod common;
pub mod energy;
pub mod eta;
pub mod exact;
pub mod maxcon;
pub mod relaxed;
pub mod sai;
pub mod sync;

use anyhow::Result;

pub use energy::{allocate_energy_constrained, AllocationOutcome};

pub use crate::costmodel::Bounds;
use crate::costmodel::LearnerCost;
use crate::staleness;

/// A complete assignment for one global cycle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    /// Local updates per learner `τ_k`.
    pub tau: Vec<u64>,
    /// Batch sizes per learner `d_k`.
    pub d: Vec<u64>,
}

impl Allocation {
    pub fn k(&self) -> usize {
        self.tau.len()
    }

    /// Maximum staleness (eq. 6).
    pub fn max_staleness(&self) -> u64 {
        staleness::max_staleness(&self.tau)
    }

    /// Average pairwise staleness (eq. 13).
    pub fn avg_staleness(&self) -> f64 {
        staleness::avg_staleness(&self.tau)
    }

    /// Cycle time of each learner under eq. (5).
    pub fn times(&self, costs: &[LearnerCost]) -> Vec<f64> {
        self.tau
            .iter()
            .zip(&self.d)
            .zip(costs)
            .map(|((&t, &d), c)| c.time(t as f64, d as f64))
            .collect()
    }

    /// Mean fraction of the cycle clock each learner is busy.
    pub fn mean_utilization(&self, costs: &[LearnerCost], t_cycle: f64) -> f64 {
        let ts = self.times(costs);
        ts.iter().map(|t| (t / t_cycle).min(1.0)).sum::<f64>() / ts.len().max(1) as f64
    }

    /// Hard-constraint check: deadlines (7b as `≤ T` after flooring),
    /// total batch (7c), bounds (7f), positivity (7d/7e — τ may be 0 only
    /// if even one epoch misses the deadline, the paper's infeasibility
    /// marker).
    pub fn validate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<(), String> {
        let k = self.k();
        if self.d.len() != k || costs.len() != k {
            return Err(format!(
                "length mismatch: tau={} d={} costs={}",
                k,
                self.d.len(),
                costs.len()
            ));
        }
        let sum: u64 = self.d.iter().sum();
        if sum != d_total {
            return Err(format!("sum d = {sum} != total {d_total}"));
        }
        for i in 0..k {
            if !bounds.contains(self.d[i]) {
                return Err(format!(
                    "d[{i}] = {} outside [{}, {}]",
                    self.d[i], bounds.d_lo, bounds.d_hi
                ));
            }
            let t = costs[i].time(self.tau[i] as f64, self.d[i] as f64);
            if t > t_cycle * (1.0 + 1e-9) {
                return Err(format!(
                    "learner {i}: t = {t:.4}s exceeds T = {t_cycle}s (tau={}, d={})",
                    self.tau[i], self.d[i]
                ));
            }
        }
        Ok(())
    }

    /// Work-conserving check for *asynchronous* allocations: each learner
    /// does the most epochs that fit in `T` (one more would miss it) —
    /// the integer realization of the full-duration equality (7b).
    pub fn is_work_conserving(&self, costs: &[LearnerCost], t_cycle: f64) -> bool {
        self.tau.iter().zip(&self.d).zip(costs).all(|((&t, &d), c)| {
            c.time((t + 1) as f64, d as f64) > t_cycle * (1.0 - 1e-12)
        })
    }
}

/// Which allocation algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocatorKind {
    /// Exact integer window search (optimality yardstick).
    Exact,
    /// Relaxed problem (8) via augmented Lagrangian + floor + SAI repair.
    Relaxed,
    /// KKT-seeded suggest-and-improve (the paper's analytical path).
    Sai,
    /// Equal task allocation, asynchronous [10].
    Eta,
    /// Synchronous MEL [9]: common τ for all learners.
    Sync,
    /// Work-max within a staleness budget of 1 (exact search in budget
    /// mode) — the paper's observed async operating point (Fig. 2).
    WorkMax,
}

impl AllocatorKind {
    pub fn name(&self) -> &'static str {
        match self {
            AllocatorKind::Exact => "exact",
            AllocatorKind::Relaxed => "relaxed",
            AllocatorKind::Sai => "sai",
            AllocatorKind::Eta => "eta",
            AllocatorKind::Sync => "sync",
            AllocatorKind::WorkMax => "workmax",
        }
    }

    /// Parse from a CLI token.
    pub fn parse(s: &str) -> Option<AllocatorKind> {
        AllocatorKind::all()
            .into_iter()
            .find(|k| k.name().eq_ignore_ascii_case(s))
    }

    /// All kinds, for sweeps.
    pub fn all() -> [AllocatorKind; 6] {
        [
            AllocatorKind::Exact,
            AllocatorKind::Relaxed,
            AllocatorKind::Sai,
            AllocatorKind::Eta,
            AllocatorKind::Sync,
            AllocatorKind::WorkMax,
        ]
    }
}

impl std::str::FromStr for AllocatorKind {
    type Err = std::io::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        AllocatorKind::parse(s).ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("unknown allocator '{s}' (exact|relaxed|sai|eta|sync|workmax)"),
            )
        })
    }
}

/// Object-safe allocator interface.
pub trait TaskAllocator {
    /// Compute an allocation for one global cycle.
    fn allocate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Allocation>;

    /// Short name for reports.
    fn name(&self) -> &'static str;
}

/// Instantiate an allocator by kind with default options.
pub fn make_allocator(kind: AllocatorKind) -> Box<dyn TaskAllocator + Send + Sync> {
    match kind {
        AllocatorKind::Exact => Box::new(exact::ExactAllocator::default()),
        AllocatorKind::Relaxed => Box::new(relaxed::RelaxedAllocator::default()),
        AllocatorKind::Sai => Box::new(sai::SaiAllocator::default()),
        AllocatorKind::Eta => Box::new(eta::EtaAllocator),
        AllocatorKind::Sync => Box::new(sync::SyncAllocator::default()),
        AllocatorKind::WorkMax => Box::new(exact::ExactAllocator {
            opts: exact::ExactOptions { staleness_budget: Some(1), ..Default::default() },
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs2() -> Vec<LearnerCost> {
        vec![
            LearnerCost::new(4.5e-4, 1e-4, 0.3),
            LearnerCost::new(1.6e-3, 1.2e-4, 0.4),
        ]
    }

    #[test]
    fn validate_catches_sum_mismatch() {
        let a = Allocation { tau: vec![2, 2], d: vec![100, 100] };
        let b = Bounds::new(50, 500);
        let err = a.validate(&costs2(), 15.0, 300, &b).unwrap_err();
        assert!(err.contains("sum"), "{err}");
    }

    #[test]
    fn validate_catches_deadline_violation() {
        let a = Allocation { tau: vec![1000, 2], d: vec![100, 100] };
        let b = Bounds::new(50, 500);
        let err = a.validate(&costs2(), 1.0, 200, &b).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn validate_catches_bounds() {
        let a = Allocation { tau: vec![1, 1], d: vec![10, 390] };
        let b = Bounds::new(50, 500);
        let err = a.validate(&costs2(), 100.0, 400, &b).unwrap_err();
        assert!(err.contains("outside"), "{err}");
    }

    #[test]
    fn work_conserving_detects_slack() {
        let costs = costs2();
        let t_cycle = 15.0;
        let d = 1000u64;
        let tau_max = costs[0].tau_max_int(d, t_cycle).unwrap();
        let good = Allocation { tau: vec![tau_max], d: vec![d] };
        assert!(good.is_work_conserving(&costs[..1], t_cycle));
        let lazy = Allocation { tau: vec![tau_max - 1], d: vec![d] };
        assert!(!lazy.is_work_conserving(&costs[..1], t_cycle));
    }

    #[test]
    fn kind_names_unique() {
        let names: Vec<_> = AllocatorKind::all().iter().map(|k| k.name()).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), 6);
        assert_eq!(dedup.len(), 6);
    }
}
