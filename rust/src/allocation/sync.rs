//! Synchronous MEL baseline — the scheme of the companion paper [9].
//!
//! All learners perform the *same* number of updates `τ` per global
//! cycle (zero staleness by construction) with `t_k ≤ T`; the batch
//! split is optimized so the common `τ` is as large as possible
//! (accuracy in synchronous MEL is maximized by maximizing τ, §III).
//! The cost is idle time: fast nodes finish early and wait — the
//! inefficiency the paper's asynchronous scheme removes.
//!
//! For a candidate τ each learner can absorb at most
//! `d̄_k(τ) = ⌊(T − C⁰_k)/(C¹_k + C²_k·τ)⌋` samples (eq. 5 at equality),
//! so τ is feasible iff `Σ min(d̄_k(τ), d_u) ≥ d` and `d̄_k(τ) ≥ d_l` for
//! enough... precisely: the capacity interval `[d_l, min(d̄_k, d_u)]`
//! must admit a point summing to `d`. Capacity is non-increasing in τ,
//! so the largest feasible τ is found by descending search.

use anyhow::{anyhow, ensure, Result};

use crate::allocation::{Allocation, TaskAllocator};
use crate::costmodel::{Bounds, LearnerCost};

/// Synchronous allocator options.
#[derive(Debug, Clone, Copy)]
pub struct SyncOptions {
    /// Safety cap on the τ search (far above anything reachable).
    pub tau_cap: u64,
}

impl Default for SyncOptions {
    fn default() -> Self {
        Self { tau_cap: 1_000_000 }
    }
}

/// Synchronous MEL baseline [9].
#[derive(Debug, Clone, Copy, Default)]
pub struct SyncAllocator {
    pub opts: SyncOptions,
}

impl SyncAllocator {
    /// Per-learner max batch at common τ, clipped to the box. `None` if
    /// learner cannot make the deadline even at `d_l`.
    fn capacity(cost: &LearnerCost, tau: u64, t_cycle: f64, bounds: &Bounds) -> Option<u64> {
        let cap = cost.d_max_int_for_tau(tau, t_cycle)?;
        if cap < bounds.d_lo {
            return None;
        }
        Some(cap.min(bounds.d_hi))
    }

    /// Is common-τ feasible? If so return the per-learner caps.
    fn feasible(
        costs: &[LearnerCost],
        tau: u64,
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Option<Vec<u64>> {
        let caps: Option<Vec<u64>> = costs
            .iter()
            .map(|c| Self::capacity(c, tau, t_cycle, bounds))
            .collect();
        let caps = caps?;
        let hi: u64 = caps.iter().sum();
        let lo: u64 = bounds.d_lo * costs.len() as u64;
        (lo <= d_total && d_total <= hi).then_some(caps)
    }
}

impl TaskAllocator for SyncAllocator {
    fn allocate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Allocation> {
        let k = costs.len();
        ensure!(k > 0, "no learners");

        // Upper bound on τ: the fastest learner at the smallest batch.
        let tau_ub = costs
            .iter()
            .filter_map(|c| c.tau_max_int(bounds.d_lo, t_cycle))
            .max()
            .ok_or_else(|| anyhow!("no learner can exchange the model within T"))?
            .min(self.opts.tau_cap);

        // Largest feasible common τ (capacity is monotone non-increasing
        // in τ, so binary search applies).
        let mut lo_t = 0u64;
        let mut hi_t = tau_ub;
        if Self::feasible(costs, hi_t, t_cycle, d_total, bounds).is_some() {
            lo_t = hi_t;
        } else {
            ensure!(
                Self::feasible(costs, 0, t_cycle, d_total, bounds).is_some(),
                "synchronous MEL infeasible even at τ = 0 (d = {d_total})"
            );
            while hi_t - lo_t > 1 {
                let mid = lo_t + (hi_t - lo_t) / 2;
                if Self::feasible(costs, mid, t_cycle, d_total, bounds).is_some() {
                    lo_t = mid;
                } else {
                    hi_t = mid;
                }
            }
        }
        let tau = lo_t;
        let caps = Self::feasible(costs, tau, t_cycle, d_total, bounds)
            .expect("binary search invariant");

        // Distribute d: start everyone at d_l, hand out the rest by
        // largest remaining capacity (water-filling keeps it inside caps).
        let mut d: Vec<u64> = vec![bounds.d_lo; k];
        let rest = d_total - bounds.d_lo * k as u64;
        // proportional-to-headroom pass
        let headroom: Vec<u64> = caps.iter().zip(&d).map(|(&c, &x)| c - x).collect();
        let total_head: u64 = headroom.iter().sum();
        ensure!(total_head >= rest, "capacity accounting violated");
        for i in 0..k {
            let give = ((headroom[i] as u128 * rest as u128) / total_head.max(1) as u128) as u64;
            d[i] += give;
        }
        let mut placed: u64 = d.iter().sum();
        // exact fix-up
        let mut idx = 0usize;
        while placed < d_total {
            if d[idx] < caps[idx] {
                d[idx] += 1;
                placed += 1;
            }
            idx = (idx + 1) % k;
        }

        // all learners run exactly the common τ — idle slack is implicit
        let alloc = Allocation { tau: vec![tau; k], d };
        debug_assert!(alloc.validate(costs, t_cycle, d_total, bounds).is_ok());
        Ok(alloc)
    }

    fn name(&self) -> &'static str {
        "sync"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn het_costs(k: usize) -> Vec<LearnerCost> {
        (0..k)
            .map(|i| {
                let c2 = if i % 2 == 0 { 4.5e-4 } else { 1.6e-3 };
                LearnerCost::new(c2, 1.1e-4, 0.35)
            })
            .collect()
    }

    #[test]
    fn staleness_is_zero_by_construction() {
        let costs = het_costs(9);
        let bounds = Bounds::proportional(27_000, 9, 0.2, 2.5);
        let a = SyncAllocator::default()
            .allocate(&costs, 15.0, 27_000, &bounds)
            .unwrap();
        assert_eq!(a.max_staleness(), 0);
        a.validate(&costs, 15.0, 27_000, &bounds).unwrap();
    }

    #[test]
    fn tau_is_maximal_common_value() {
        let costs = het_costs(6);
        let d_total = 18_000;
        let bounds = Bounds::proportional(d_total, 6, 0.2, 2.5);
        let t_cycle = 15.0;
        let a = SyncAllocator::default()
            .allocate(&costs, t_cycle, d_total, &bounds)
            .unwrap();
        let tau = a.tau[0];
        // τ+1 must be infeasible
        assert!(
            SyncAllocator::feasible(&costs, tau + 1, t_cycle, d_total, &bounds).is_none(),
            "τ={tau} should be maximal"
        );
    }

    #[test]
    fn sync_wastes_fast_node_time() {
        // the motivating inefficiency: with sync, fast learners idle
        let costs = het_costs(8);
        let d_total = 24_000;
        let bounds = Bounds::proportional(d_total, 8, 0.2, 2.5);
        let t_cycle = 7.5;
        let a = SyncAllocator::default()
            .allocate(&costs, t_cycle, d_total, &bounds)
            .unwrap();
        let util = a.mean_utilization(&costs, t_cycle);
        assert!(util < 0.999, "sync should not be fully work-conserving: {util}");
    }

    #[test]
    fn infeasible_when_total_exceeds_capacity() {
        let costs = het_costs(2);
        let bounds = Bounds::new(1, 2_000);
        // 2 learners × cap 2000 < 10_000
        assert!(SyncAllocator::default()
            .allocate(&costs, 7.5, 10_000, &bounds)
            .is_err());
    }
}
