//! The paper's analytical path: KKT-structured **suggest** followed by
//! **suggest-and-improve** to integer feasibility (§IV, Appendices A/B).
//!
//! Theorem 1 gives the stationary point structure of the relaxed
//! problem: with the box multipliers `ν, ν'` inactive, eq. (11) reads
//! `τ*_k = −(λ_k C¹_k + ω)/(λ_k C²_k)` with the pair multipliers `μ, μ'`
//! (through `u, u'`, eqs. 19–24) pushing the `τ_k` *toward each other* —
//! at the unconstrained optimum the interior learners share a **common
//! τ̄**, and each `d_k` follows from the full-duration equality (8c).
//! Learners whose forced batch `d_k(τ̄)` leaves the box [d_l, d_u] pin
//! to the boundary (their `ν/ν'` activate) and deviate minimally.
//!
//! The **suggest** step therefore reduces to a one-dimensional root
//! find: the largest τ̄ with `Σ_k clamp(d_k(τ̄), d_l, d_u) ≥ d` — a
//! non-increasing function, handled by [`bisect_decreasing`]. The
//! **improve** step is the shared integer local search in
//! [`common::improve_to_local_optimum`].

use anyhow::{anyhow, ensure, Result};

use crate::allocation::{common, Allocation, TaskAllocator};
use crate::costmodel::{Bounds, LearnerCost};
use crate::solver::bisect_decreasing;

/// Options for [`SaiAllocator`].
#[derive(Debug, Clone, Copy)]
pub struct SaiOptions {
    /// Bisection tolerance on τ̄.
    pub tau_tol: f64,
    /// Improve-loop round cap.
    pub improve_rounds: usize,
}

impl Default for SaiOptions {
    fn default() -> Self {
        Self { tau_tol: 1e-9, improve_rounds: 400 }
    }
}

/// Continuous suggestion produced by the KKT-structured suggest step.
#[derive(Debug, Clone)]
pub struct Suggestion {
    /// The common interior τ̄.
    pub tau_bar: f64,
    /// Clamped continuous batches at τ̄ (before sum correction).
    pub d: Vec<f64>,
    /// Which learners pinned to a box face (ν or ν' active).
    pub clamped: Vec<bool>,
}

/// KKT-seeded suggest-and-improve allocator (the paper's "SAI" curve).
#[derive(Debug, Clone, Copy, Default)]
pub struct SaiAllocator {
    pub opts: SaiOptions,
}

impl SaiAllocator {
    /// Total clamped batch demand at common τ (non-increasing in τ).
    fn total_at_tau(costs: &[LearnerCost], tau: f64, t_cycle: f64, bounds: &Bounds) -> f64 {
        costs
            .iter()
            .map(|c| {
                c.d_of_tau(tau, t_cycle)
                    .map(|d| d.clamp(bounds.d_lo as f64, bounds.d_hi as f64))
                    .unwrap_or(0.0)
            })
            .sum()
    }

    /// The suggest step: common τ̄ + clamped batches.
    pub fn suggest(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Suggestion> {
        // τ upper bracket: fastest learner at the smallest batch
        let tau_ub = costs
            .iter()
            .filter_map(|c| c.tau_of_d(bounds.d_lo as f64, t_cycle))
            .fold(f64::NAN, f64::max);
        ensure!(
            tau_ub.is_finite() && tau_ub >= 0.0,
            "no learner can exchange the model within T = {t_cycle}s"
        );
        let target = d_total as f64;
        let tau_bar = bisect_decreasing(0.0, tau_ub.max(1e-9), self.opts.tau_tol, target, |t| {
            Self::total_at_tau(costs, t, t_cycle, bounds)
        })
        .ok_or_else(|| {
            anyhow!(
                "Σ clamp(d_k(0)) = {:.1} < d = {d_total}: infeasible even at τ = 0",
                Self::total_at_tau(costs, 0.0, t_cycle, bounds)
            )
        })?;

        let mut d = Vec::with_capacity(costs.len());
        let mut clamped = Vec::with_capacity(costs.len());
        for c in costs {
            let raw = c.d_of_tau(tau_bar, t_cycle).unwrap_or(0.0);
            let cl = raw.clamp(bounds.d_lo as f64, bounds.d_hi as f64);
            clamped.push((cl - raw).abs() > 1e-9);
            d.push(cl);
        }
        // shave any surplus off the *interior* learners proportionally so
        // Σ d = d exactly (keeps clamped learners on their KKT face)
        let sum: f64 = d.iter().sum();
        let surplus = sum - target;
        if surplus > 1e-9 {
            let interior: f64 = d
                .iter()
                .zip(&clamped)
                .filter(|(_, &cl)| !cl)
                .map(|(&v, _)| v - bounds.d_lo as f64)
                .sum();
            if interior > surplus {
                for (v, &cl) in d.iter_mut().zip(&clamped) {
                    if !cl {
                        *v -= surplus * (*v - bounds.d_lo as f64) / interior;
                    }
                }
            }
        }
        Ok(Suggestion { tau_bar, d, clamped })
    }
}

impl TaskAllocator for SaiAllocator {
    fn allocate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Allocation> {
        ensure!(!costs.is_empty(), "no learners");
        let sug = self.suggest(costs, t_cycle, d_total, bounds)?;
        let mut d = common::integerize_batches(&sug.d, d_total, bounds)
            .ok_or_else(|| anyhow!("bounds make Σd = {d_total} unreachable"))?;
        let alloc =
            common::improve_to_local_optimum(costs, &mut d, t_cycle, bounds, self.opts.improve_rounds);
        debug_assert!(alloc.validate(costs, t_cycle, d_total, bounds).is_ok());
        Ok(alloc)
    }

    fn name(&self) -> &'static str {
        "sai"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::eta::EtaAllocator;

    fn het_costs(k: usize) -> Vec<LearnerCost> {
        (0..k)
            .map(|i| {
                let c2 = if i % 2 == 0 { 4.5e-4 } else { 1.6e-3 };
                LearnerCost::new(c2, 1.1e-4 + 1e-5 * (i % 4) as f64, 0.3 + 0.04 * (i % 3) as f64)
            })
            .collect()
    }

    #[test]
    fn suggest_hits_total_exactly_when_interior() {
        let costs = het_costs(8);
        let d_total = 24_000u64;
        let bounds = Bounds::proportional(d_total, 8, 0.2, 2.5);
        let s = SaiAllocator::default()
            .suggest(&costs, 15.0, d_total, &bounds)
            .unwrap();
        let sum: f64 = s.d.iter().sum();
        assert!((sum - d_total as f64).abs() < 1.0, "sum={sum}");
        assert!(s.tau_bar > 0.0);
    }

    #[test]
    fn suggest_common_tau_for_unclamped_learners() {
        let costs = het_costs(10);
        let d_total = 30_000u64;
        let bounds = Bounds::proportional(d_total, 10, 0.2, 2.5);
        let t_cycle = 15.0;
        let s = SaiAllocator::default()
            .suggest(&costs, t_cycle, d_total, &bounds)
            .unwrap();
        for (i, (&di, &cl)) in s.d.iter().zip(&s.clamped).enumerate() {
            if !cl {
                // interior learners sit on the t = T manifold at τ̄ (before
                // the proportional shave, which only moves them slightly)
                let tau_i = costs[i].tau_of_d(di, t_cycle).unwrap();
                assert!(
                    (tau_i - s.tau_bar).abs() < 0.35,
                    "learner {i}: τ={tau_i} vs τ̄={}",
                    s.tau_bar
                );
            }
        }
    }

    #[test]
    fn sai_feasible_work_conserving_and_beats_eta() {
        for k in [6usize, 10, 16, 20] {
            let costs = het_costs(k);
            let d_total = 3_000 * k as u64;
            let bounds = Bounds::proportional(d_total, k, 0.2, 2.5);
            for t_cycle in [7.5, 15.0] {
                let sai = SaiAllocator::default()
                    .allocate(&costs, t_cycle, d_total, &bounds)
                    .unwrap();
                sai.validate(&costs, t_cycle, d_total, &bounds).unwrap();
                assert!(sai.is_work_conserving(&costs, t_cycle));
                let eta = EtaAllocator.allocate(&costs, t_cycle, d_total, &bounds).unwrap();
                assert!(
                    sai.max_staleness() <= eta.max_staleness(),
                    "k={k} T={t_cycle}: sai {} > eta {}",
                    sai.max_staleness(),
                    eta.max_staleness()
                );
            }
        }
    }

    #[test]
    fn sai_near_zero_staleness_on_wide_bounds() {
        // with a loose box the KKT point is interior -> staleness ≤ 1
        let costs = het_costs(12);
        let d_total = 36_000u64;
        let bounds = Bounds::proportional(d_total, 12, 0.05, 4.0);
        let a = SaiAllocator::default()
            .allocate(&costs, 15.0, d_total, &bounds)
            .unwrap();
        assert!(a.max_staleness() <= 1, "tau={:?}", a.tau);
    }

    #[test]
    fn errors_when_infeasible_at_tau_zero() {
        // one slow link: even τ = 0 can't place d within bounds
        let costs = vec![LearnerCost::new(1e-3, 0.5, 5.0)]; // 0.5 s per sample comms
        let bounds = Bounds::new(1, 100_000);
        assert!(SaiAllocator::default()
            .allocate(&costs, 7.5, 50_000, &bounds)
            .is_err());
    }
}
