//! Shared integer machinery: flooring, residual redistribution, and the
//! **improve** half of suggest-and-improve (§IV-A).
//!
//! Both the relaxed-numerical and the SAI-analytical paths end with a
//! continuous suggestion that must be turned into a feasible *integer*
//! point: floor τ, re-derive work-conserving τ from integer d, restore
//! `Σ d_k = d` (7c) without leaving the box (7f), then locally improve
//! staleness by moving samples between the extremal-τ learners.

use crate::allocation::Allocation;
use crate::costmodel::{Bounds, LearnerCost};

/// Turn a continuous batch vector into integers inside the box with the
/// exact total: floor, then hand the residual to the learners with the
/// largest fractional parts (largest-remainder method), clamped to
/// bounds; any remaining excess/deficit is fixed by ±1 sweeps.
pub fn integerize_batches(
    d_real: &[f64],
    d_total: u64,
    bounds: &Bounds,
) -> Option<Vec<u64>> {
    let k = d_real.len();
    if (bounds.d_lo * k as u64) > d_total || (bounds.d_hi * k as u64) < d_total {
        return None; // box makes the simplex empty
    }
    let mut d: Vec<u64> = d_real
        .iter()
        .map(|&v| bounds.clamp(v.floor().max(0.0) as u64))
        .collect();

    // largest-remainder pass
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| {
        let fa = d_real[a] - d_real[a].floor();
        let fb = d_real[b] - d_real[b].floor();
        fb.partial_cmp(&fa).unwrap()
    });
    let mut sum: i64 = d.iter().map(|&v| v as i64).sum();
    let target = d_total as i64;
    for &i in &order {
        if sum >= target {
            break;
        }
        if d[i] < bounds.d_hi {
            d[i] += 1;
            sum += 1;
        }
    }
    // final ±1 sweeps (handles clamping distortions)
    let mut guard = 0usize;
    while sum != target {
        guard += 1;
        if guard > 10 * k * (bounds.d_hi - bounds.d_lo + 1) as usize {
            return None;
        }
        let mut moved = false;
        for i in 0..k {
            if sum < target && d[i] < bounds.d_hi {
                d[i] += 1;
                sum += 1;
                moved = true;
            } else if sum > target && d[i] > bounds.d_lo {
                d[i] -= 1;
                sum -= 1;
                moved = true;
            }
            if sum == target {
                break;
            }
        }
        if !moved {
            return None;
        }
    }
    Some(d)
}

/// Work-conserving τ for integer batches: each learner runs as many
/// epochs as fit in `T` (the integer realization of eq. 7b). Learners
/// for whom even the model exchange misses `T` get τ = 0 — the paper's
/// "MEL not feasible for learner k" marker.
pub fn work_conserving_tau(costs: &[LearnerCost], d: &[u64], t_cycle: f64) -> Vec<u64> {
    costs
        .iter()
        .zip(d)
        .map(|(c, &di)| c.tau_max_int(di, t_cycle).unwrap_or(0))
        .collect()
}

/// One *improve* descent: move samples from the min-τ learner (taking
/// data raises its τ) to the max-τ learner (adding data lowers its τ),
/// by the smallest amounts that change each extremal τ by one, while
/// honoring bounds and `Σ d = d`. Returns `true` if staleness strictly
/// improved (lexicographic on (max, avg)).
fn improve_once(
    costs: &[LearnerCost],
    d: &mut Vec<u64>,
    tau: &mut Vec<u64>,
    t_cycle: f64,
    bounds: &Bounds,
) -> bool {
    let k = costs.len();
    let cur = Allocation { tau: tau.clone(), d: d.clone() };
    let cur_key = (cur.max_staleness(), cur.avg_staleness());
    if cur_key.0 == 0 {
        return false;
    }
    let hi = (0..k).max_by_key(|&i| tau[i]).unwrap();
    let lo = (0..k).min_by_key(|&i| tau[i]).unwrap();
    if tau[hi] == tau[lo] {
        return false;
    }

    // smallest extra data that drops τ_hi by one:
    //   need d_hi' > d_max_int_for_tau(τ_hi)
    let need_hi = costs[hi]
        .d_max_int_for_tau(tau[hi], t_cycle)
        .map(|dm| dm.saturating_add(1).saturating_sub(d[hi]))
        .unwrap_or(u64::MAX);
    // smallest data removal that raises τ_lo by one:
    //   need d_lo' ≤ d_max_int_for_tau(τ_lo + 1)
    let need_lo = costs[lo]
        .d_max_int_for_tau(tau[lo] + 1, t_cycle)
        .map(|dm| d[lo].saturating_sub(dm))
        .unwrap_or(u64::MAX);

    // capacity on each side
    let room_hi = bounds.d_hi.saturating_sub(d[hi]);
    let room_lo = d[lo].saturating_sub(bounds.d_lo);

    // candidate transfer sizes, smallest effective first
    let mut cands: Vec<u64> = Vec::new();
    if need_hi > 0 && need_hi <= room_hi.min(room_lo) {
        cands.push(need_hi);
    }
    if need_lo > 0 && need_lo <= room_hi.min(room_lo) {
        cands.push(need_lo);
    }
    cands.sort_unstable();
    cands.dedup();

    for delta in cands {
        let mut d2 = d.clone();
        d2[lo] -= delta;
        d2[hi] += delta;
        let tau2 = work_conserving_tau(costs, &d2, t_cycle);
        let a2 = Allocation { tau: tau2.clone(), d: d2.clone() };
        let key2 = (a2.max_staleness(), a2.avg_staleness());
        if key2 < cur_key {
            *d = d2;
            *tau = tau2;
            return true;
        }
    }
    false
}

/// The improve loop of SAI: repeat single-move descents to a local
/// optimum (bounded rounds; each round strictly improves, and staleness
/// is a nonnegative integer pair, so termination is guaranteed anyway).
pub fn improve_to_local_optimum(
    costs: &[LearnerCost],
    d: &mut Vec<u64>,
    t_cycle: f64,
    bounds: &Bounds,
    max_rounds: usize,
) -> Allocation {
    let mut tau = work_conserving_tau(costs, d, t_cycle);
    for _ in 0..max_rounds {
        if !improve_once(costs, d, &mut tau, t_cycle, bounds) {
            break;
        }
    }
    Allocation { tau, d: d.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn het_costs(k: usize) -> Vec<LearnerCost> {
        (0..k)
            .map(|i| {
                let fast = i % 2 == 0;
                let c2 = if fast { 4.5e-4 } else { 1.6e-3 };
                LearnerCost::new(c2, 1.1e-4, 0.35)
            })
            .collect()
    }

    #[test]
    fn integerize_preserves_total_and_bounds() {
        let bounds = Bounds::new(100, 2000);
        let d_real = [433.7, 1200.2, 999.9, 366.2];
        let d = integerize_batches(&d_real, 3000, &bounds).unwrap();
        assert_eq!(d.iter().sum::<u64>(), 3000);
        for &v in &d {
            assert!(bounds.contains(v));
        }
    }

    #[test]
    fn integerize_handles_heavy_clamping() {
        let bounds = Bounds::new(500, 800);
        // all suggestions below the box -> clamped up, then trimmed down
        let d_real = [100.0, 100.0, 100.0, 100.0];
        let d = integerize_batches(&d_real, 2400, &bounds).unwrap();
        assert_eq!(d.iter().sum::<u64>(), 2400);
        for &v in &d {
            assert!(bounds.contains(v));
        }
    }

    #[test]
    fn integerize_rejects_empty_simplex() {
        let bounds = Bounds::new(100, 200);
        assert!(integerize_batches(&[150.0, 150.0], 1000, &bounds).is_none());
        assert!(integerize_batches(&[150.0, 150.0], 100, &bounds).is_none());
    }

    #[test]
    fn work_conserving_tau_is_maximal() {
        let costs = het_costs(4);
        let d = [1000u64, 1000, 1000, 1000];
        let t_cycle = 7.5;
        let tau = work_conserving_tau(&costs, &d, t_cycle);
        for i in 0..4 {
            let t_now = costs[i].time(tau[i] as f64, d[i] as f64);
            let t_next = costs[i].time((tau[i] + 1) as f64, d[i] as f64);
            assert!(t_now <= t_cycle && t_next > t_cycle);
        }
    }

    #[test]
    fn improve_reduces_staleness_from_equal_split() {
        let costs = het_costs(10);
        let t_cycle = 7.5;
        let d_total = 30_000u64;
        let bounds = Bounds::proportional(d_total, 10, 0.2, 2.5);
        let mut d = vec![d_total / 10; 10];
        let before =
            Allocation { tau: work_conserving_tau(&costs, &d, t_cycle), d: d.clone() };
        let after = improve_to_local_optimum(&costs, &mut d, t_cycle, &bounds, 200);
        assert!(after.max_staleness() <= before.max_staleness());
        assert!(
            after.max_staleness() < before.max_staleness()
                || after.avg_staleness() <= before.avg_staleness(),
            "no progress: before={} after={}",
            before.max_staleness(),
            after.max_staleness()
        );
        after
            .validate(&costs, t_cycle, d_total, &bounds)
            .expect("improved allocation stays feasible");
        assert!(after.is_work_conserving(&costs, t_cycle));
    }

    #[test]
    fn improve_stops_at_zero_staleness() {
        // homogeneous fleet: equal split is already optimal
        let costs: Vec<LearnerCost> =
            (0..6).map(|_| LearnerCost::new(1e-3, 1e-4, 0.3)).collect();
        let bounds = Bounds::new(100, 10_000);
        let mut d = vec![1000u64; 6];
        let a = improve_to_local_optimum(&costs, &mut d, 15.0, &bounds, 50);
        assert_eq!(a.max_staleness(), 0);
        assert_eq!(d, vec![1000u64; 6]);
    }
}
