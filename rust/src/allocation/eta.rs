//! Asynchronous **Equal Task Allocation** baseline ([10], the scheme the
//! paper's Fig. 2/3 compare against).
//!
//! Every learner gets the same batch `d/K` (remainder spread one sample
//! at a time), then runs as many epochs as fit in the cycle clock. No
//! staleness control whatsoever — fast laptops race ahead of RPi-class
//! nodes, which is exactly the gap the paper's optimizer closes.

use anyhow::{ensure, Result};

use crate::allocation::{common, Allocation, TaskAllocator};
use crate::costmodel::{Bounds, LearnerCost};

/// Equal-task-allocation (asynchronous) baseline.
#[derive(Debug, Clone, Copy, Default)]
pub struct EtaAllocator;

impl TaskAllocator for EtaAllocator {
    fn allocate(
        &self,
        costs: &[LearnerCost],
        t_cycle: f64,
        d_total: u64,
        bounds: &Bounds,
    ) -> Result<Allocation> {
        let k = costs.len();
        ensure!(k > 0, "no learners");
        let base = d_total / k as u64;
        let rem = (d_total % k as u64) as usize;
        ensure!(
            bounds.contains(base) && (rem == 0 || bounds.contains(base + 1)),
            "equal share {base} falls outside bounds [{}, {}]",
            bounds.d_lo,
            bounds.d_hi
        );
        let d: Vec<u64> = (0..k)
            .map(|i| if i < rem { base + 1 } else { base })
            .collect();
        let tau = common::work_conserving_tau(costs, &d, t_cycle);
        Ok(Allocation { tau, d })
    }

    fn name(&self) -> &'static str {
        "eta"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn het_costs(k: usize) -> Vec<LearnerCost> {
        (0..k)
            .map(|i| {
                let c2 = if i % 2 == 0 { 4.5e-4 } else { 1.6e-3 };
                LearnerCost::new(c2, 1.1e-4, 0.35)
            })
            .collect()
    }

    #[test]
    fn equal_shares_sum_exactly() {
        let costs = het_costs(7);
        let bounds = Bounds::new(1, 100_000);
        let a = EtaAllocator.allocate(&costs, 15.0, 60_001, &bounds).unwrap();
        assert_eq!(a.d.iter().sum::<u64>(), 60_001);
        let spread = a.d.iter().max().unwrap() - a.d.iter().min().unwrap();
        assert!(spread <= 1);
        a.validate(&costs, 15.0, 60_001, &bounds).unwrap();
    }

    #[test]
    fn heterogeneous_fleet_gets_nonzero_staleness() {
        let costs = het_costs(10);
        let bounds = Bounds::new(1, 100_000);
        let a = EtaAllocator.allocate(&costs, 7.5, 30_000, &bounds).unwrap();
        assert!(
            a.max_staleness() >= 2,
            "fast/slow 3.5x c2 gap must show up: tau={:?}",
            a.tau
        );
        assert!(a.is_work_conserving(&costs, 7.5));
    }

    #[test]
    fn rejects_share_outside_bounds() {
        let costs = het_costs(4);
        let bounds = Bounds::new(500, 600);
        assert!(EtaAllocator.allocate(&costs, 15.0, 10_000, &bounds).is_err());
    }
}
