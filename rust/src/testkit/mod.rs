//! Tiny property-testing harness (no proptest in this registry).
//!
//! [`forall`] runs a property over `cases` pseudo-random inputs drawn
//! through [`Gen`]; on failure it panics with the case index and the
//! seed that reproduces it. No shrinking — failures print their full
//! generated input via the property's own panic message instead.

use crate::sim::Rng;

/// Random input generator handed to properties.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    /// Vector of `len` draws from `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Access the raw RNG (for shuffles etc.).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated inputs. The property panics (via
/// assert!) to signal failure; we re-panic with reproduction info.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let base_seed = 0xF0A11u64 ^ (name.len() as u64) << 32 ^ fxhash(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64 * 0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Stable tiny string hash for per-property seed derivation.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_true_property() {
        forall("sum-commutes", 50, |g| {
            let a = g.u64_in(0, 1000);
            let b = g.u64_in(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn forall_reports_failures_with_seed() {
        forall("always-fails", 10, |g| {
            let v = g.u64_in(0, 10);
            assert!(v > 100, "v was {v}");
        });
    }

    #[test]
    fn gen_ranges_are_respected() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.u64_in(5, 9);
            assert!((5..=9).contains(&v));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        assert_eq!(g.u64_in(7, 7), 7);
    }

    #[test]
    fn gen_vec_has_len() {
        let mut g = Gen::new(2);
        let v = g.vec(17, |g| g.bool());
        assert_eq!(v.len(), 17);
    }

    #[test]
    fn same_seed_same_stream() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        for _ in 0..10 {
            assert_eq!(a.u64_in(0, 1_000_000), b.u64_in(0, 1_000_000));
        }
    }
}
