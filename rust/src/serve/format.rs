//! Pluggable result-encoding layer for the `serve` daemon.
//!
//! Results (and only results — checkpoints have their own fixed JSON
//! format, see [`crate::coordinator::checkpoint`]) stream through a
//! serde-style [`Format`] object so a future wire format (CSV, a binary
//! framing, …) plugs in without touching the daemon loop. The first and
//! default implementation is JSON over the in-tree [`crate::json`]
//! substrate.

use anyhow::{bail, Result};

use crate::json::{self, Value};

/// A result encoding: turns the daemon's [`Value`] trees into text and
/// back. Implementations must be pure (same value → same text) so
/// digest comparisons across daemon restarts stay meaningful.
pub trait Format: Send + Sync {
    /// Short name, as accepted by `asyncmel serve --format`.
    fn name(&self) -> &'static str;
    /// MIME-style content type (informational).
    fn content_type(&self) -> &'static str;
    /// File extension including the dot (e.g. `.json`).
    fn extension(&self) -> &'static str;
    /// Encode a value to text.
    fn write_value(&self, v: &Value) -> String;
    /// Decode text back into a value.
    fn read_value(&self, text: &str) -> Result<Value>;
}

/// JSON over the in-tree [`crate::json`] module.
pub struct JsonFormat {
    /// Pretty-print (spool files); compact is the stdin line protocol.
    pub pretty: bool,
}

impl Format for JsonFormat {
    fn name(&self) -> &'static str {
        if self.pretty {
            "json"
        } else {
            "json-compact"
        }
    }

    fn content_type(&self) -> &'static str {
        "application/json"
    }

    fn extension(&self) -> &'static str {
        ".json"
    }

    fn write_value(&self, v: &Value) -> String {
        if self.pretty {
            v.pretty()
        } else {
            v.compact()
        }
    }

    fn read_value(&self, text: &str) -> Result<Value> {
        json::parse(text)
    }
}

/// Resolve a `--format` name to an implementation.
pub fn make_format(name: &str) -> Result<Box<dyn Format>> {
    match name {
        "json" => Ok(Box::new(JsonFormat { pretty: true })),
        "json-compact" => Ok(Box::new(JsonFormat { pretty: false })),
        other => bail!("unknown result format '{other}' (known: json, json-compact)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips_and_is_pure() {
        let fmt = make_format("json").unwrap();
        let mut v = Value::obj();
        v.set("id", "job-1")
            .set("records", Value::Arr(vec![Value::from(1.5f64), Value::from(2u64)]));
        let text = fmt.write_value(&v);
        assert_eq!(text, fmt.write_value(&v), "encoding must be pure");
        let back = fmt.read_value(&text).unwrap();
        assert_eq!(back.str_field("id").unwrap(), "job-1");
    }

    #[test]
    fn compact_variant_has_no_newlines() {
        let fmt = make_format("json-compact").unwrap();
        let mut v = Value::obj();
        v.set("a", 1u64).set("b", 2u64);
        assert!(!fmt.write_value(&v).contains('\n'));
    }

    #[test]
    fn unknown_format_is_rejected_by_name() {
        let err = make_format("msgpack").unwrap_err().to_string();
        assert!(err.contains("msgpack"), "{err}");
    }
}
