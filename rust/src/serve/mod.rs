//! Service mode: the `asyncmel serve` daemon.
//!
//! A long-running process that accepts scenario/workload submissions and
//! runs them on the existing [`crate::coordinator::EventEngine`]
//! machinery, streaming results back through a pluggable
//! [`format::Format`] layer (JSON first, over the in-tree
//! [`crate::json`] substrate).
//!
//! # Spool protocol
//!
//! The daemon watches a spool directory:
//!
//! ```text
//! spool/
//!   <id>.json          # submissions land here (atomically rename in!)
//!   work/<id>.json     # claimed — the daemon owns the job now
//!   ckpt/<id>.ckpt.json# suspended engine state (see below)
//!   out/<id>.result.json  # the finished run, via the Format layer
//!   out/<id>.digest    # canonical record digest, for bit-identity cmp
//!   out/<id>.error     # quarantine note for rejected submissions
//!   done/<id>.json     # processed submissions (success or poison)
//! ```
//!
//! Jobs are claimed oldest-name-first by `rename(2)` into `work/`, so a
//! submission is never half-read and a crashed daemon leaves claimed
//! jobs where its successor will find them. On startup the daemon
//! first resumes everything in `work/` — from its checkpoint if one
//! exists — before looking at new arrivals.
//!
//! # Checkpoint/restore
//!
//! With `--checkpoint-every N` the daemon runs each job in segments of
//! `N` global cycles via
//! [`crate::coordinator::engine::EventEngine::run_to_checkpoint`],
//! serializing the complete engine state (event queue, RNG streams,
//! fleet, allocation, fading, counters) at an aggregation boundary
//! after each segment. A killed daemon restarted over the same spool
//! resumes from the last checkpoint and produces records, final
//! parameters and [`EngineStats`] **bit-identical** to an uninterrupted
//! run — the digest files let CI `cmp` the two.
//!
//! # Submission schema
//!
//! ```json
//! {
//!   "id": "job-1",
//!   "scenario": { ... ScenarioConfig JSON ... },
//!   "run": { "cycles": 50, "policy": "async", "alpha": 0.6,
//!            "scheme": "eta", "eval_every": 1 }
//! }
//! ```
//!
//! Unknown keys anywhere are rejected (same contract as the scenario
//! config loader). Scenarios whose `multimodel` block
//! [`MultiModelConfig::is_multi`] routes to the multi-model engine
//! path; `run.policy` is ignored there (that path is always
//! per-arrival asynchronous).

pub mod format;

use std::fs;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::aggregation::{AggregationRule, AsyncAggregator, StalenessDecay};
use crate::allocation::AllocatorKind;
use crate::config::ScenarioConfig;
use crate::coordinator::checkpoint::record_to_json;
use crate::coordinator::engine::{MultiRunOutcome, RunOutcome};
use crate::coordinator::{
    record_digest, CycleRecord, EngineCheckpoint, EngineOptions, EnginePolicy, EngineStats,
    EventEngine, ExecMode, MultiModelCheckpoint, TrainOptions,
};
use crate::json::{self, Value};
use crate::multimodel::{report_digest, MultiModelConfig, MultiModelOptions, MultiModelReport};

pub use format::{make_format, Format, JsonFormat};

/// Serve-side unknown-key guard (the scenario config keeps its own
/// private copy for its sections; submissions add layers above it).
fn reject_unknown_keys(v: &Value, known: &[&str], section: &str) -> Result<()> {
    if let Value::Obj(m) = v {
        for key in m.keys() {
            ensure!(
                known.contains(&key.as_str()),
                "unknown key '{key}' in {section} (known: {})",
                known.join(", ")
            );
        }
    }
    Ok(())
}

/// How to drive the engine for one submission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunSpec {
    /// Global cycles to run.
    pub cycles: usize,
    /// `true` = lock-step barrier aggregation; `false` = per-arrival
    /// staleness-weighted async (the default).
    pub barrier: bool,
    /// Async base mixing rate `α` (ignored under barrier).
    pub alpha: f64,
    /// Task-allocation scheme.
    pub scheme: AllocatorKind,
    /// Evaluate every `eval_every` cycles.
    pub eval_every: usize,
}

impl RunSpec {
    /// Parse the submission's `"run"` object; unknown keys are
    /// rejected, absent optional keys take the async defaults.
    pub fn from_json(v: &Value) -> Result<Self> {
        reject_unknown_keys(v, &["cycles", "policy", "alpha", "scheme", "eval_every"], "run spec")?;
        let cycles = v.usize_field("cycles").context("run spec")?;
        ensure!(cycles >= 1, "run spec needs cycles >= 1");
        let barrier = match v.get("policy") {
            None => false,
            Some(p) => match p.as_str().context("run policy")? {
                "async" => false,
                "barrier" => true,
                other => bail!("run policy must be 'async' or 'barrier', got '{other}'"),
            },
        };
        let alpha = match v.get("alpha") {
            None => 0.6,
            Some(a) => a.as_f64().context("run alpha")?,
        };
        ensure!(alpha > 0.0 && alpha <= 1.0, "run alpha must be in (0, 1], got {alpha}");
        let scheme = match v.get("scheme") {
            None => AllocatorKind::Eta,
            Some(s) => {
                let name = s.as_str().context("run scheme")?;
                AllocatorKind::parse(name)
                    .ok_or_else(|| anyhow!("unknown allocation scheme '{name}'"))?
            }
        };
        let eval_every = match v.get("eval_every") {
            None => 1,
            Some(e) => e.as_usize().context("run eval_every")?,
        };
        ensure!(eval_every >= 1, "run eval_every must be >= 1");
        Ok(Self { cycles, barrier, alpha, scheme, eval_every })
    }

    fn aggregator(&self) -> AsyncAggregator {
        AsyncAggregator::new(self.alpha, StalenessDecay::Polynomial { a: 0.5 })
    }

    /// Single-model engine options for this spec.
    pub fn engine_options(&self) -> EngineOptions {
        EngineOptions {
            train: TrainOptions {
                cycles: self.cycles,
                eval_every: self.eval_every,
                ..TrainOptions::default()
            },
            policy: if self.barrier {
                EnginePolicy::Barrier
            } else {
                EnginePolicy::Async(self.aggregator())
            },
        }
    }

    /// Multi-model engine options, wiring the scenario's declarative
    /// `multimodel` block through.
    pub fn multi_options(&self, multi: &MultiModelConfig) -> MultiModelOptions {
        MultiModelOptions {
            train: TrainOptions {
                cycles: self.cycles,
                eval_every: self.eval_every,
                ..TrainOptions::default()
            },
            aggregator: self.aggregator(),
            multi: multi.clone(),
            round_budgets: Vec::new(),
            target_accuracies: Vec::new(),
        }
    }
}

/// One unit of daemon work: a scenario plus how to run it.
#[derive(Debug, Clone)]
pub struct Submission {
    pub id: String,
    pub scenario: ScenarioConfig,
    pub run: RunSpec,
}

impl Submission {
    /// Parse a `{"id", "scenario", "run"}` submission; the scenario is
    /// any sparse [`ScenarioConfig`] JSON (paper defaults fill gaps).
    pub fn from_json(v: &Value) -> Result<Self> {
        reject_unknown_keys(v, &["id", "scenario", "run"], "submission")?;
        let id = v.str_field("id")?.to_string();
        ensure!(
            !id.is_empty()
                && id.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "submission id must be non-empty [A-Za-z0-9_-], got '{id}'"
        );
        let scenario =
            ScenarioConfig::from_json(v.field("scenario")?).context("submission scenario")?;
        let run = RunSpec::from_json(v.field("run")?).context("submission run spec")?;
        Ok(Self { id, scenario, run })
    }

    /// Parse a submission from JSON text (one spool file / stdin line).
    pub fn parse(text: &str) -> Result<Self> {
        Self::from_json(&json::parse(text).context("parsing submission JSON")?)
    }
}

/// Daemon configuration (`asyncmel serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Spool directory root (created if missing).
    pub spool: PathBuf,
    /// Process everything currently queued, then exit instead of
    /// polling.
    pub once: bool,
    /// Idle poll interval.
    pub poll_ms: u64,
    /// Checkpoint each job every this many global cycles (0 = never —
    /// jobs run start-to-finish in one segment).
    pub checkpoint_every: usize,
    /// Stop the daemon after this many checkpointed segments — the CI
    /// harness's deterministic stand-in for `kill -9`.
    pub stop_after_segments: Option<usize>,
    /// Result encoding, by [`make_format`] name.
    pub format: String,
    /// Read compact one-line submissions from stdin instead of watching
    /// the spool (results still land in `spool/out/`).
    pub stdin: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            spool: PathBuf::from("spool"),
            once: false,
            poll_ms: 200,
            checkpoint_every: 0,
            stop_after_segments: None,
            format: "json".to_string(),
            stdin: false,
        }
    }
}

/// What one daemon lifetime accomplished.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeSummary {
    pub jobs_completed: usize,
    pub jobs_failed: usize,
    /// Jobs left suspended (checkpoint on disk, submission still
    /// claimed in `work/`) when the daemon stopped.
    pub jobs_suspended: usize,
    /// Engine segments run (a completed job counts its final segment).
    pub segments: usize,
}

/// The spool directory layout.
pub struct Spool {
    pub root: PathBuf,
    pub work: PathBuf,
    pub ckpt: PathBuf,
    pub out: PathBuf,
    pub done: PathBuf,
}

impl Spool {
    /// Create the layout under `root` (idempotent).
    pub fn prepare(root: &Path) -> Result<Spool> {
        let spool = Spool {
            root: root.to_path_buf(),
            work: root.join("work"),
            ckpt: root.join("ckpt"),
            out: root.join("out"),
            done: root.join("done"),
        };
        for dir in [&spool.root, &spool.work, &spool.ckpt, &spool.out, &spool.done] {
            fs::create_dir_all(dir)
                .with_context(|| format!("creating spool dir {}", dir.display()))?;
        }
        // sweep half-written tmp files from a crashed predecessor: a
        // kill between `write` and `rename` in `write_atomic` leaves a
        // `*.tmp` behind, and the job that owned it will re-run anyway
        for dir in [&spool.ckpt, &spool.out, &spool.done] {
            for entry in fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
                let path = entry?.path();
                if path.is_file() && path.extension().and_then(|e| e.to_str()) == Some("tmp") {
                    fs::remove_file(&path)
                        .with_context(|| format!("sweeping stale {}", path.display()))?;
                }
            }
        }
        Ok(spool)
    }

    fn ckpt_path(&self, id: &str) -> PathBuf {
        self.ckpt.join(format!("{id}.ckpt.json"))
    }
}

/// `*.json` files directly inside `dir`, oldest name first (submitters
/// who want FIFO should use sortable names, e.g. zero-padded counters).
fn sorted_json_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let path = entry?.path();
        if path.is_file() && path.extension().and_then(|e| e.to_str()) == Some("json") {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

/// Crash-safe write: results and digests appear atomically or not at
/// all (the checkpoint layer has the same tmp+rename discipline).
/// The tmp name appends `.tmp` to the *full* filename rather than
/// swapping the extension, so `{id}.digest` and `{id}.error` for the
/// same job never collide on one tmp path.
fn write_atomic(path: &Path, text: &str) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path).with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

fn stats_to_json(s: &EngineStats) -> Value {
    let mut v = Value::obj();
    v.set("events", s.events)
        .set("joins", s.joins)
        .set("leaves", s.leaves)
        .set("dispatched", s.dispatched)
        .set("arrivals", s.arrivals)
        .set("resolves", s.resolves)
        .set("final_alive", s.final_alive)
        .set("retries", s.retries)
        .set("timeouts", s.timeouts)
        .set("dupes_dropped", s.dupes_dropped)
        .set("corrupt_dropped", s.corrupt_dropped)
        .set("degraded_boundaries", s.degraded_boundaries);
    v
}

fn single_result_json(id: &str, records: &[CycleRecord], stats: &EngineStats) -> Value {
    let mut v = Value::obj();
    v.set("id", id)
        .set("kind", "single")
        .set("records", Value::Arr(records.iter().map(record_to_json).collect()))
        .set("stats", stats_to_json(stats));
    v
}

fn multi_result_json(id: &str, report: &MultiModelReport) -> Value {
    let mut models = Vec::with_capacity(report.num_models());
    for (m, records) in report.records.iter().enumerate() {
        let s = &report.stats[m];
        let mut mv = Value::obj();
        mv.set("model", s.model)
            .set("weight", s.weight)
            .set("arrivals", s.arrivals)
            .set("applied", s.applied)
            .set("assigned_slots", s.assigned_slots)
            .set("final_sum_d", s.final_sum_d.map_or(Value::Null, Value::from))
            .set("budget_cycle", s.budget_cycle.map_or(Value::Null, Value::from))
            .set("target_cycle", s.target_cycle.map_or(Value::Null, Value::from))
            .set("final_buffer", s.final_buffer)
            .set("retunes", s.retunes)
            .set("records", Value::Arr(records.iter().map(record_to_json).collect()));
        models.push(mv);
    }
    let mut v = Value::obj();
    v.set("id", id).set("kind", "multi").set("models", Value::Arr(models));
    v
}

/// Move a bad submission out of the way with a note, so one poison job
/// cannot wedge the queue. Best-effort: quarantine failures must not
/// take the daemon down.
fn poison(spool: &Spool, job_path: &Path, id: &str, err: &anyhow::Error) {
    eprintln!("serve: job '{id}' failed: {err:#}");
    let _ = write_atomic(&spool.out.join(format!("{id}.error")), &format!("{err:#}\n"));
    let _ = fs::remove_file(spool.ckpt_path(id));
    if let Some(name) = job_path.file_name() {
        let _ = fs::rename(job_path, spool.done.join(name));
    }
}

enum JobStep {
    Finished,
    Suspended,
}

/// Where to suspend the next segment: `checkpoint_every` more recorded
/// cycles, or never. The engine finishes (does not suspend) when the
/// stop lands at/after the run's cycle budget.
fn segment_stop(done: usize, checkpoint_every: usize) -> Option<usize> {
    if checkpoint_every == 0 {
        None
    } else {
        Some(done + checkpoint_every)
    }
}

/// Drive one engine segment for a claimed job: build a fresh engine
/// (the daemon may have been killed and restarted since the last
/// segment — nothing is carried in memory), resume from the on-disk
/// checkpoint if one exists, and either suspend again or finish.
fn run_one_segment(
    sub: &Submission,
    spool: &Spool,
    job_path: &Path,
    fmt: &dyn Format,
    checkpoint_every: usize,
) -> Result<JobStep> {
    let ckpt_path = spool.ckpt_path(&sub.id);
    let mut engine = EventEngine::new(
        sub.scenario.build(),
        sub.run.scheme,
        AggregationRule::FedAvg,
        ExecMode::Phantom,
    )?;

    let (result, digest, step) = if sub.scenario.multimodel.is_multi() {
        let resume = if ckpt_path.exists() {
            Some(MultiModelCheckpoint::load(&ckpt_path)?)
        } else {
            None
        };
        let done = resume.as_ref().map_or(0, |ck| ck.done_cycles);
        let opts = sub.run.multi_options(&sub.scenario.multimodel);
        match engine.run_multi_to_checkpoint(&opts, resume, segment_stop(done, checkpoint_every))? {
            MultiRunOutcome::Suspended(ck) => {
                ck.save(&ckpt_path)?;
                return Ok(JobStep::Suspended);
            }
            MultiRunOutcome::Finished(report) => {
                let digest = report_digest(&report);
                (multi_result_json(&sub.id, &report), digest, JobStep::Finished)
            }
        }
    } else {
        let resume =
            if ckpt_path.exists() { Some(EngineCheckpoint::load(&ckpt_path)?) } else { None };
        let done = resume.as_ref().map_or(0, |ck| ck.records.len());
        let opts = sub.run.engine_options();
        match engine.run_to_checkpoint(&opts, resume, segment_stop(done, checkpoint_every))? {
            RunOutcome::Suspended(ck) => {
                ck.save(&ckpt_path)?;
                return Ok(JobStep::Suspended);
            }
            RunOutcome::Finished { records, .. } => {
                let digest = record_digest(&records);
                (single_result_json(&sub.id, &records, &engine.stats), digest, JobStep::Finished)
            }
        }
    };

    write_atomic(
        &spool.out.join(format!("{}.result{}", sub.id, fmt.extension())),
        &fmt.write_value(&result),
    )?;
    write_atomic(&spool.out.join(format!("{}.digest", sub.id)), &digest)?;
    let _ = fs::remove_file(&ckpt_path);
    let name = job_path.file_name().ok_or_else(|| anyhow!("job path has no file name"))?;
    fs::rename(job_path, spool.done.join(name))
        .with_context(|| format!("retiring {}", job_path.display()))?;
    Ok(step)
}

/// Run a claimed job segment-by-segment until it finishes (or the
/// segment budget says the daemon should stop). Returns `true` when the
/// daemon should exit with the job left suspended.
fn drive_job(
    sub: &Submission,
    spool: &Spool,
    job_path: &Path,
    fmt: &dyn Format,
    opts: &ServeOptions,
    summary: &mut ServeSummary,
) -> bool {
    loop {
        match run_one_segment(sub, spool, job_path, fmt, opts.checkpoint_every) {
            Ok(JobStep::Finished) => {
                summary.segments += 1;
                summary.jobs_completed += 1;
                println!("serve: job '{}' finished", sub.id);
                return false;
            }
            Ok(JobStep::Suspended) => {
                summary.segments += 1;
                if opts.stop_after_segments.is_some_and(|max| summary.segments >= max) {
                    summary.jobs_suspended += 1;
                    println!("serve: stopping after {} segment(s), job '{}' suspended", summary.segments, sub.id);
                    return true;
                }
            }
            Err(e) => {
                poison(spool, job_path, &sub.id, &e);
                summary.jobs_failed += 1;
                return false;
            }
        }
    }
}

/// The daemon loop. Returns when `once` drains the queue, when
/// `stop_after_segments` is hit, or (stdin mode) at end-of-input.
pub fn serve(opts: &ServeOptions) -> Result<ServeSummary> {
    let fmt = make_format(&opts.format)?;
    let spool = Spool::prepare(&opts.spool)?;
    let mut summary = ServeSummary::default();

    if opts.stdin {
        use std::io::BufRead;
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            let line = line.context("reading stdin submission")?;
            let text = line.trim();
            if text.is_empty() || text.starts_with('#') {
                continue;
            }
            let sub = match Submission::parse(text) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("serve: rejected stdin submission: {e:#}");
                    summary.jobs_failed += 1;
                    continue;
                }
            };
            // Materialize the submission so a kill mid-run leaves the
            // same claimed-job + checkpoint state as spool mode.
            let job_path = spool.work.join(format!("{}.json", sub.id));
            write_atomic(&job_path, text)?;
            if drive_job(&sub, &spool, &job_path, fmt.as_ref(), opts, &mut summary) {
                return Ok(summary);
            }
        }
        return Ok(summary);
    }

    loop {
        // Claim new arrivals. Jobs already in work/ (a previous daemon's
        // claims) sort in with them and resume from their checkpoints.
        for path in sorted_json_files(&spool.root)? {
            let Some(name) = path.file_name() else { continue };
            fs::rename(&path, spool.work.join(name))
                .with_context(|| format!("claiming {}", path.display()))?;
        }
        let claimed = sorted_json_files(&spool.work)?;
        if claimed.is_empty() {
            if opts.once {
                return Ok(summary);
            }
            std::thread::sleep(Duration::from_millis(opts.poll_ms.max(1)));
            continue;
        }
        for job_path in claimed {
            let stem = job_path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("job")
                .to_string();
            let text = match fs::read_to_string(&job_path) {
                Ok(t) => t,
                Err(e) => {
                    poison(&spool, &job_path, &stem, &anyhow!(e));
                    summary.jobs_failed += 1;
                    continue;
                }
            };
            let sub = match Submission::parse(&text) {
                Ok(s) => s,
                Err(e) => {
                    poison(&spool, &job_path, &stem, &e);
                    summary.jobs_failed += 1;
                    continue;
                }
            };
            if drive_job(&sub, &spool, &job_path, fmt.as_ref(), opts, &mut summary) {
                return Ok(summary);
            }
        }
        if opts.once {
            return Ok(summary);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multimodel::SchedulerKind;

    fn test_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("asyncmel-serve-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn submission_text(id: &str, k: usize, seed: u64, cycles: usize) -> String {
        let cfg = ScenarioConfig::paper_default().with_learners(k).with_seed(seed);
        let mut run = Value::obj();
        run.set("cycles", cycles).set("policy", "async").set("alpha", 0.6).set("scheme", "eta");
        let mut v = Value::obj();
        v.set("id", id).set("scenario", cfg.to_json()).set("run", run);
        v.compact()
    }

    fn reference_digest(text: &str) -> String {
        let sub = Submission::parse(text).unwrap();
        let mut engine = EventEngine::new(
            sub.scenario.build(),
            sub.run.scheme,
            AggregationRule::FedAvg,
            ExecMode::Phantom,
        )
        .unwrap();
        let records = engine.run(&sub.run.engine_options()).unwrap();
        record_digest(&records)
    }

    #[test]
    fn submission_rejects_unknown_keys_and_bad_run_specs() {
        let text = submission_text("job-x", 4, 1, 3);
        let sub = Submission::parse(&text).unwrap();
        assert_eq!(sub.id, "job-x");
        assert_eq!(sub.run.cycles, 3);
        assert!(matches!(sub.run.engine_options().policy, EnginePolicy::Async(_)));

        let mut v = json::parse(&text).unwrap();
        v.set("surprise", 1u64);
        assert!(Submission::from_json(&v).unwrap_err().to_string().contains("surprise"));

        let mut v = json::parse(&text).unwrap();
        let mut run = Value::obj();
        run.set("cycles", 3u64).set("policy", "semi-sync");
        v.set("run", run);
        assert!(Submission::from_json(&v).is_err());

        let mut v = json::parse(&text).unwrap();
        v.set("id", "bad id with spaces");
        assert!(Submission::from_json(&v).is_err());
    }

    #[test]
    fn spool_job_completes_and_digest_matches_direct_run() {
        let dir = test_dir("complete");
        let text = submission_text("job-a", 4, 11, 4);
        fs::write(dir.join("job-a.json"), &text).unwrap();
        let opts = ServeOptions { spool: dir.clone(), once: true, ..Default::default() };
        let summary = serve(&opts).unwrap();
        assert_eq!(summary.jobs_completed, 1);
        assert_eq!(summary.jobs_failed, 0);
        assert_eq!(summary.segments, 1);

        let digest = fs::read_to_string(dir.join("out/job-a.digest")).unwrap();
        assert_eq!(digest, reference_digest(&text));
        assert!(dir.join("done/job-a.json").exists(), "submission retired to done/");
        assert!(!dir.join("ckpt/job-a.ckpt.json").exists(), "no stray checkpoint");

        let result =
            json::parse(&fs::read_to_string(dir.join("out/job-a.result.json")).unwrap()).unwrap();
        assert_eq!(result.str_field("kind").unwrap(), "single");
        assert_eq!(result.field("records").unwrap().as_arr().unwrap().len(), 4);
        assert!(result.field("stats").unwrap().u64_field("events").unwrap() > 0);
    }

    #[test]
    fn killed_daemon_resumes_bit_identically_from_its_checkpoint() {
        let dir = test_dir("resume");
        let text = submission_text("job-r", 5, 23, 6);
        fs::write(dir.join("job-r.json"), &text).unwrap();

        // First daemon lifetime: checkpoint every 2 cycles, "die" after
        // the first suspension.
        let first = ServeOptions {
            spool: dir.clone(),
            once: true,
            checkpoint_every: 2,
            stop_after_segments: Some(1),
            ..Default::default()
        };
        let summary = serve(&first).unwrap();
        assert_eq!(summary.segments, 1);
        assert_eq!(summary.jobs_suspended, 1);
        assert_eq!(summary.jobs_completed, 0);
        assert!(dir.join("ckpt/job-r.ckpt.json").exists());
        assert!(dir.join("work/job-r.json").exists(), "suspended job stays claimed");

        // Second lifetime: fresh process state, picks the claimed job up
        // from its checkpoint and drives it home.
        let second = ServeOptions {
            spool: dir.clone(),
            once: true,
            checkpoint_every: 2,
            ..Default::default()
        };
        let summary = serve(&second).unwrap();
        assert_eq!(summary.jobs_completed, 1);
        assert!(summary.segments >= 2, "resumed run needs further segments");

        let digest = fs::read_to_string(dir.join("out/job-r.digest")).unwrap();
        assert_eq!(digest, reference_digest(&text), "restore must be bit-identical");
        assert!(!dir.join("ckpt/job-r.ckpt.json").exists(), "checkpoint cleaned up");
        assert!(dir.join("done/job-r.json").exists());
    }

    #[test]
    fn malformed_submission_is_quarantined_and_the_rest_proceed() {
        let dir = test_dir("poison");
        fs::write(dir.join("aaa-bad.json"), "{ this is not json").unwrap();
        let text = submission_text("job-ok", 4, 3, 3);
        fs::write(dir.join("zzz-ok.json"), &text).unwrap();
        let opts = ServeOptions { spool: dir.clone(), once: true, ..Default::default() };
        let summary = serve(&opts).unwrap();
        assert_eq!(summary.jobs_failed, 1);
        assert_eq!(summary.jobs_completed, 1);
        assert!(dir.join("out/aaa-bad.error").exists());
        assert!(dir.join("done/aaa-bad.json").exists(), "poison job moved aside");
        assert!(dir.join("out/job-ok.digest").exists());
    }

    #[test]
    fn multi_model_submission_routes_to_the_multi_engine() {
        let dir = test_dir("multi");
        let mut cfg = ScenarioConfig::paper_default().with_learners(6).with_seed(9);
        cfg.multimodel = MultiModelConfig::new(2, 1, SchedulerKind::RoundRobin);
        let mut run = Value::obj();
        run.set("cycles", 4u64);
        let mut v = Value::obj();
        v.set("id", "job-m").set("scenario", cfg.to_json()).set("run", run);
        fs::write(dir.join("job-m.json"), v.compact()).unwrap();

        let opts = ServeOptions { spool: dir.clone(), once: true, ..Default::default() };
        let summary = serve(&opts).unwrap();
        assert_eq!(summary.jobs_completed, 1);

        let result =
            json::parse(&fs::read_to_string(dir.join("out/job-m.result.json")).unwrap()).unwrap();
        assert_eq!(result.str_field("kind").unwrap(), "multi");
        assert_eq!(result.field("models").unwrap().as_arr().unwrap().len(), 2);
        assert!(dir.join("out/job-m.digest").exists());
    }
}
