//! ABL-2: solver scaling + optimality-gap harness.
//!
//! Times each allocation scheme across fleet sizes K (the orchestrator
//! pays this once per global cycle) and prints the staleness objective
//! side by side with the exact optimum — the quantitative version of the
//! paper's "the analytical approximation closely matched the solution of
//! the numerical solvers" (§VI). The gap table is skipped under
//! `--smoke`; `--json PATH` writes machine-readable results
//! (scripts/bench_check.sh).

use asyncmel::allocation::{make_allocator, AllocatorKind};
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::ScenarioConfig;
use asyncmel::metrics::{fmt_f, Table};

fn print_gap_table() {
    println!("\n============ ABL-2 — objective gap vs exact ============");
    let mut t = Table::new(&["K", "T(s)", "exact", "relaxed", "sai", "eta"]);
    for &t_cycle in &[7.5, 15.0] {
        for k in [5usize, 10, 15, 20, 30] {
            let scenario = ScenarioConfig::paper_default()
                .with_learners(k)
                .with_cycle(t_cycle)
                .build();
            let mut cells = vec![k.to_string(), fmt_f(t_cycle, 1)];
            for kind in [
                AllocatorKind::Exact,
                AllocatorKind::Relaxed,
                AllocatorKind::Sai,
                AllocatorKind::Eta,
            ] {
                let a = make_allocator(kind)
                    .allocate(
                        &scenario.costs,
                        scenario.t_cycle(),
                        scenario.total_samples(),
                        &scenario.bounds,
                    )
                    .expect("allocation");
                cells.push(a.max_staleness().to_string());
            }
            t.row(&cells);
        }
    }
    println!("{}", t.render());
    println!("=========================================================\n");
}

fn main() {
    let mut run = BenchRun::from_env("solver_bench");
    if !run.smoke() {
        print_gap_table();
    }

    let cfg = BenchConfig::default();
    for kind in [AllocatorKind::Exact, AllocatorKind::Relaxed, AllocatorKind::Sai] {
        group(&format!("solve scaling — {}", kind.name()));
        for k in [5usize, 10, 20, 40] {
            let scenario = ScenarioConfig::paper_default()
                .with_learners(k)
                .with_cycle(7.5)
                .build();
            let alloc = make_allocator(kind);
            run.bench(&format!("{}/K={k}", kind.name()), &cfg, || {
                alloc
                    .allocate(
                        &scenario.costs,
                        scenario.t_cycle(),
                        scenario.total_samples(),
                        &scenario.bounds,
                    )
                    .unwrap()
            });
        }
    }

    run.finish().expect("bench json");
}
