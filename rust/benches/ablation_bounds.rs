//! ABL-1: batch-bounds sensitivity (regeneration harness + timing).
//!
//! Prints the staleness-vs-(d_l, d_u) table justifying the default
//! (0.2, 2.5)·d/K box (skipped under `--smoke`), and times the SAI
//! allocator under the tightest and loosest boxes (box width changes
//! the improve-loop work). `--json PATH` writes machine-readable
//! results (scripts/bench_check.sh).

use asyncmel::allocation::{make_allocator, AllocatorKind};
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::ScenarioConfig;
use asyncmel::experiments::ablation;

fn main() {
    let mut run = BenchRun::from_env("ablation_bounds");
    if !run.smoke() {
        let params = ablation::AblationParams::default();
        let rows = ablation::run(&params).expect("ablation sweep");
        println!("\n========= ABL-1 — staleness vs batch bounds (7f) =========");
        println!("{}", ablation::table(&rows).render());
        println!("==========================================================\n");
    }

    group("sai allocator by bounds width @ K=20");
    let cfg = BenchConfig::default();
    for (lo, hi) in [(0.9, 1.1), (0.2, 2.5), (0.05, 8.0)] {
        let scenario = ScenarioConfig::paper_default()
            .with_learners(20)
            .with_cycle(7.5)
            .with_bound_fracs(lo, hi)
            .build();
        let alloc = make_allocator(AllocatorKind::Sai);
        run.bench(&format!("sai/bounds=({lo},{hi})"), &cfg, || {
            alloc
                .allocate(
                    &scenario.costs,
                    scenario.t_cycle(),
                    scenario.total_samples(),
                    &scenario.bounds,
                )
                .unwrap()
        });
    }

    run.finish().expect("bench json");
}
