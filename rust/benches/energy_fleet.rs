//! Bench for the **energy-constrained fleet**: budgeted allocation and
//! battery-driven churn on the event engine.
//!
//! `cargo bench --bench energy_fleet` does two things:
//! 1. verifies the energy contracts end-to-end (skipped under
//!    `--smoke`; also asserted in `rust/tests/energy_path.rs`):
//!    a budget-∞ run is byte-identical to a run that never touches the
//!    energy path, and battery-driven churn is bit-identical across
//!    `--shards {1, 8}`;
//! 2. times a K = 5000 phantom async fleet (a) with a finite
//!    per-learner budget routing every re-solve through the
//!    energy-feasible clipping wrapper, and (b) with batteries + duty
//!    cycling, where every dispatch bills a battery and depletion
//!    feeds Leave/Rejoin back through the churn path.
//!
//! Passthrough flags: `--smoke` (fast CI config), `--json PATH`
//! (machine-readable results; see scripts/bench_check.sh).

use asyncmel::aggregation::{AggregationRule, AsyncAggregator};
use asyncmel::allocation::AllocatorKind;
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::{ChurnConfig, EnergyConfig, ScenarioConfig};
use asyncmel::coordinator::{
    record_digest, EngineOptions, EnginePolicy, EventEngine, ExecMode, TrainOptions,
};

const K: usize = 5000;
const CYCLES: usize = 6;

/// A cap that clamps the 2–3 GHz laptop class (~20 J work-conserving
/// rounds at the paper defaults) but not the embedded class (~0.5 J).
const BUDGET_J: f64 = 12.0;

fn battery_cfg() -> EnergyConfig {
    EnergyConfig {
        battery_lo_j: 40.0,
        battery_hi_j: 80.0,
        battery_floor_j: 0.5,
        recharge_s: 30.0,
        ..EnergyConfig::disabled()
    }
}

fn engine(energy: Option<EnergyConfig>, shards: usize) -> EventEngine<'static> {
    let mut base = ScenarioConfig::paper_default()
        .with_learners(K)
        .with_churn(ChurnConfig::new(1.0, 120.0));
    if let Some(e) = energy {
        base = base.with_energy(e).unwrap();
    }
    EventEngine::new(
        base.build(),
        AllocatorKind::Eta,
        AggregationRule::FedAvg,
        ExecMode::Phantom,
    )
    .unwrap()
    .with_shards(shards)
}

fn opts() -> EngineOptions {
    EngineOptions {
        train: TrainOptions { cycles: CYCLES, ..Default::default() },
        policy: EnginePolicy::Async(AsyncAggregator::default()),
    }
}

fn verify_contracts() {
    println!("\n========== ENERGY FLEET — contract checks ==========");
    // budget-∞ must be byte-identical to the energy-free path
    let bare = record_digest(&engine(None, 1).run(&opts()).unwrap());
    let inf = EnergyConfig { budget_j: f64::INFINITY, ..EnergyConfig::disabled() };
    let unconstrained = record_digest(&engine(Some(inf), 1).run(&opts()).unwrap());
    assert_eq!(bare, unconstrained, "budget-∞ diverged from the unconstrained oracle");
    println!("budget-∞ oracle {} — byte-identical", &bare[..16]);

    // battery-driven churn must be bit-identical across shard counts
    let mut flat = engine(Some(battery_cfg()), 1);
    let flat_digest = record_digest(&flat.run(&opts()).unwrap());
    let flat_stats = flat.stats;
    let mut sharded = engine(Some(battery_cfg()), 8);
    let sharded_digest = record_digest(&sharded.run(&opts()).unwrap());
    assert_eq!(flat_digest, sharded_digest, "battery churn diverged at 8 shards");
    assert_eq!(flat_stats, sharded.stats, "battery churn stats diverged at 8 shards");
    assert!(flat_stats.leaves > 0, "batteries never depleted — dead contract check");
    println!(
        "battery churn digest {} @ shards {{1, 8}} — bit-identical ({} leaves)",
        &flat_digest[..16],
        flat_stats.leaves
    );
    println!("====================================================\n");
}

fn main() {
    let mut run = BenchRun::from_env("energy_fleet");
    if !run.smoke() {
        verify_contracts();
    }

    group("energy fleet @ K=5000, 6 cycles, async (phantom)");
    let cfg = BenchConfig {
        measure: std::time::Duration::from_secs(5),
        max_iters: 20,
        ..Default::default()
    };
    let budget = EnergyConfig { budget_j: BUDGET_J, ..EnergyConfig::disabled() };
    run.bench("async_k5000_budget", &cfg, || {
        let mut e = engine(Some(budget), 1);
        e.run(&opts()).unwrap()
    });
    run.bench("async_k5000_battery", &cfg, || {
        let mut e = engine(Some(battery_cfg()), 1);
        e.run(&opts()).unwrap()
    });

    run.finish().expect("bench json");
}
