//! Bench + regeneration harness for **Fig. 3** (accuracy vs global cycle).
//!
//! Needs `make artifacts`. Two parts:
//! 1. regeneration: runs a scaled-down Fig.-3 workload (12k samples,
//!    K = 10, 8 cycles — CI-sized; the paper-scale run is
//!    `examples/train_e2e.rs` / `asyncmel fig3`; skipped under
//!    `--smoke`);
//! 2. timing: one full global cycle of the stack (allocation + dispatch
//!    + τ_k SGD epochs through PJRT + aggregation + eval) — the
//!    end-to-end hot path.
//!
//! Without artifacts the target skips loudly but still writes its
//! (empty) `--json` report so CI tooling sees a well-formed file.

use asyncmel::aggregation::AggregationRule;
use asyncmel::allocation::AllocatorKind;
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::ScenarioConfig;
use asyncmel::coordinator::{Orchestrator, TrainOptions};
use asyncmel::data::{synth, SynthConfig};
use asyncmel::experiments::fig3;
use asyncmel::runtime::{default_artifacts_dir, Runtime};

const SAMPLES: usize = 12_000;

fn print_figure_curves(rt: &Runtime) {
    let base = ScenarioConfig::paper_default()
        .with_cycle(15.0)
        .with_total_samples(SAMPLES as u64);
    let params = fig3::Fig3Params {
        base,
        ks: vec![10],
        schemes: vec![
            AllocatorKind::Relaxed,
            AllocatorKind::Sync,
            AllocatorKind::Eta,
        ],
        cycles: 8,
        lr: 0.01,
        data: SynthConfig { train: SAMPLES, test: 2_000, ..SynthConfig::default() },
        ..Default::default()
    };
    let curves = fig3::run(rt, &params).expect("fig3 curves");
    println!("\n=========== FIG 3 — accuracy vs global cycles ===========");
    println!("{}", fig3::table(&curves).render());
    println!("{}", fig3::summary_table(&curves, &[0.95, 0.97]).render());
    println!("(scaled workload: d={SAMPLES}; paper-scale via examples/train_e2e.rs)");
    println!("=========================================================\n");
}

fn main() {
    let mut run = BenchRun::from_env("fig3_accuracy");
    let rt = match Runtime::load(default_artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!(
                "fig3 bench skipped: artifacts not available ({e:#}). Run `make artifacts`."
            );
            run.finish().expect("bench json");
            return;
        }
    };
    if !run.smoke() {
        print_figure_curves(&rt);
    }

    group("end-to-end global cycle");
    let ds = synth::generate(&SynthConfig {
        train: 6_000,
        test: 1_024,
        ..SynthConfig::default()
    });
    let scenario = ScenarioConfig::paper_default()
        .with_learners(10)
        .with_cycle(15.0)
        .with_total_samples(6_000)
        .build();
    run.bench("global_cycle/k10_d6000", &BenchConfig::slow(), || {
        let mut orch = Orchestrator::new(
            scenario.clone(),
            AllocatorKind::Relaxed,
            AggregationRule::FedAvg,
            &rt,
            ds.train.clone(),
            ds.test.clone(),
        )
        .unwrap();
        orch.run(&TrainOptions {
            cycles: 1,
            lr: 0.01,
            eval_every: 1,
            reallocate_each_cycle: false,
        })
        .unwrap()
    });

    run.finish().expect("bench json");
}
