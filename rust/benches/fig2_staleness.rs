//! Bench + regeneration harness for **Fig. 2** (staleness vs K).
//!
//! `cargo bench --bench fig2_staleness` does two things:
//! 1. prints the full figure table (the regeneration harness — the rows
//!    the paper plots, recorded in EXPERIMENTS.md; skipped under
//!    `--smoke`);
//! 2. times the per-cycle allocation solve for each scheme at the
//!    paper's largest operating point (K = 20) — the L3 hot path.
//!
//! Passthrough flags: `--smoke` (fast CI config), `--json PATH`
//! (machine-readable results; see scripts/bench_check.sh).

use asyncmel::allocation::{make_allocator, AllocatorKind};
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::ScenarioConfig;
use asyncmel::experiments::fig2;

fn print_figure_table() {
    let params = fig2::Fig2Params { seeds: 5, ..Default::default() };
    let rows = fig2::run(&params).expect("fig2 sweep");
    println!("\n================ FIG 2 — staleness vs K ================");
    println!("{}", fig2::table(&rows).render());
    if let Some((om, em, oa, ea)) = fig2::headline(&rows) {
        println!("§V-B headline @ K=20,T=7.5s: max {om:.2} vs ETA {em:.2} (paper 1 vs 4); avg {oa:.2} vs ETA {ea:.2} (paper 0.5 vs 1.5)");
    }
    println!("=========================================================\n");
}

fn main() {
    let mut run = BenchRun::from_env("fig2_staleness");
    if !run.smoke() {
        print_figure_table();
    }

    group("allocate @ K=20, T=7.5s (per-cycle orchestrator hot path)");
    let cfg = BenchConfig::default();
    for kind in AllocatorKind::all() {
        let scenario = ScenarioConfig::paper_default()
            .with_learners(20)
            .with_cycle(7.5)
            .build();
        let alloc = make_allocator(kind);
        run.bench(&format!("allocate/{}", kind.name()), &cfg, || {
            alloc
                .allocate(
                    &scenario.costs,
                    scenario.t_cycle(),
                    scenario.total_samples(),
                    &scenario.bounds,
                )
                .unwrap()
        });
    }

    run.finish().expect("bench json");
}
