//! Serial vs **sharded** real-numerics fleet runs — the ROADMAP
//! "ExecMode::Real past a few hundred learners" acceptance harness.
//!
//! `cargo bench --bench real_fleet` does three things:
//! 1. prints the real-numerics sweep table: K ∈ {100, 500, 1000}
//!    learners running actual SGD through the native MLP executor, at
//!    `--threads 1` vs `--threads 4` (`experiments::fleet_scale::run_real`);
//! 2. asserts the determinism contract: the sharded run's record stream
//!    is byte-identical to the serial one at the headline K;
//! 3. times serial vs sharded wall clock at the largest K via benchkit
//!    (the ISSUE acceptance comparison — speedup printed at the end).
//!
//! Passthrough flags: `--smoke` (K = 50, 1 cycle CI config), `--json
//! PATH` (machine-readable results; see scripts/bench_check.sh).

use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::coordinator::record_digest;
use asyncmel::experiments::fleet_scale::{self, RealFleetParams};
use asyncmel::runtime::Runtime;

fn main() {
    let mut run = BenchRun::from_env("real_fleet");
    let params = if run.smoke() {
        RealFleetParams {
            ks: vec![50],
            cycles: 1,
            samples_per_learner: 20,
            test_samples: 256,
            ..Default::default()
        }
    } else {
        RealFleetParams::default()
    };

    println!("\n===== REAL FLEET — ExecMode::Real, serial vs sharded =====");
    let rows = fleet_scale::run_real(&params).expect("real fleet sweep");
    println!("{}", fleet_scale::real_table(&rows).render());
    println!("==========================================================\n");

    // Determinism contract at every K: sharded == serial, byte for byte.
    for pair in rows.chunks(params.threads.len()) {
        for r in &pair[1..] {
            assert_eq!(
                pair[0].digest, r.digest,
                "K={}: {} threads changed the record stream",
                r.k, r.threads
            );
        }
    }
    println!("determinism: sharded record streams match serial byte-for-byte OK\n");

    // Timed comparison at the largest K (dataset + runtime built once,
    // outside the timed region).
    let k = *params.ks.last().expect("non-empty ks");
    let runtime = Runtime::native(&params.dims, params.train_batch, params.eval_batch);
    let ds = fleet_scale::real_dataset(&params, k);
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(0),
        measure: std::time::Duration::from_secs(8),
        max_iters: 5,
        min_iters: 2,
    };
    group(&format!("real-numerics engine @ K={k} ({} cycles)", params.cycles));
    let mut wall: Vec<(usize, f64)> = Vec::new();
    let mut digests: Vec<String> = Vec::new();
    for &threads in &params.threads {
        let stats = run.bench(&format!("real_fleet/k{k}/threads{threads}"), &cfg, || {
            fleet_scale::real_engine_run(&params, k, threads, &runtime, &ds).expect("engine run")
        });
        wall.push((threads, stats.mean_s));
        let records =
            fleet_scale::real_engine_run(&params, k, threads, &runtime, &ds).expect("engine run");
        digests.push(record_digest(&records));
    }
    for d in &digests[1..] {
        assert_eq!(&digests[0], d, "timed runs diverged across thread counts");
    }
    if wall.len() >= 2 {
        let serial = wall[0].1;
        for &(threads, t) in &wall[1..] {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            println!(
                "speedup @ K={k}: {:.2}x with --threads {threads} vs --threads {} \
                 ({cores} cores available)",
                serial / t,
                wall[0].0
            );
        }
    }

    run.finish().expect("bench json");
}
