//! Serial vs **sharded** real-numerics fleet runs — the ROADMAP
//! "ExecMode::Real past a few hundred learners" acceptance harness.
//!
//! `cargo bench --bench real_fleet` does four things:
//! 1. prints the real-numerics sweep table: K ∈ {100, 500, 1000}
//!    learners running actual SGD through the native MLP executor, at
//!    `--threads 1` vs `--threads 4` (`experiments::fleet_scale::run_real`);
//! 2. asserts the determinism contract: the sharded run's record stream
//!    is byte-identical to the serial one at the headline K;
//! 3. times serial vs sharded wall clock at the largest K via benchkit
//!    (the barrier-mode acceptance comparison — speedup printed at the
//!    end);
//! 4. times the **async** policy serial vs sharded (per-event) vs
//!    sharded + ε-window arrival coalescing — the hot-path overhaul
//!    acceptance case: coalescing at 8 threads must beat per-event
//!    serial dispatch on steps/sec (both recorded in the bench JSON,
//!    with coalescing thread-invariance asserted byte-for-byte);
//! 5. times the **hierarchical sharded coordinator** on a phantom
//!    K = 100 000 async fleet at `--shards` 1 vs 8 (the 500k-scale
//!    enabler), and asserts the shard-count determinism contract:
//!    records + engine stats bit-identical across shard counts
//!    {1, 2, 8}.
//!
//! Passthrough flags: `--smoke` (K = 50, 1 cycle CI config), `--json
//! PATH` (machine-readable results; see scripts/bench_check.sh).

use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::coordinator::record_digest;
use asyncmel::experiments::fleet_scale::{self, RealFleetParams};
use asyncmel::runtime::Runtime;

fn main() {
    let mut run = BenchRun::from_env("real_fleet");
    let params = if run.smoke() {
        RealFleetParams {
            ks: vec![50],
            cycles: 1,
            samples_per_learner: 20,
            test_samples: 256,
            ..Default::default()
        }
    } else {
        RealFleetParams::default()
    };

    println!("\n===== REAL FLEET — ExecMode::Real, serial vs sharded =====");
    let rows = fleet_scale::run_real(&params).expect("real fleet sweep");
    println!("{}", fleet_scale::real_table(&rows).render());
    println!("==========================================================\n");

    // Determinism contract at every K: sharded == serial, byte for byte.
    for pair in rows.chunks(params.threads.len()) {
        for r in &pair[1..] {
            assert_eq!(
                pair[0].digest, r.digest,
                "K={}: {} threads changed the record stream",
                r.k, r.threads
            );
        }
    }
    println!("determinism: sharded record streams match serial byte-for-byte OK\n");

    // Timed comparison at the largest K (dataset + runtime built once,
    // outside the timed region).
    let k = *params.ks.last().expect("non-empty ks");
    let runtime = Runtime::native(&params.dims, params.train_batch, params.eval_batch);
    let ds = fleet_scale::real_dataset(&params, k);
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(0),
        measure: std::time::Duration::from_secs(8),
        max_iters: 5,
        min_iters: 2,
    };
    group(&format!("real-numerics engine @ K={k} ({} cycles)", params.cycles));
    let mut wall: Vec<(usize, f64)> = Vec::new();
    let mut digests: Vec<String> = Vec::new();
    for &threads in &params.threads {
        let stats = run.bench(&format!("real_fleet/k{k}/threads{threads}"), &cfg, || {
            fleet_scale::real_engine_run(&params, k, threads, &runtime, &ds).expect("engine run")
        });
        wall.push((threads, stats.mean_s));
        let records =
            fleet_scale::real_engine_run(&params, k, threads, &runtime, &ds).expect("engine run");
        digests.push(record_digest(&records));
    }
    for d in &digests[1..] {
        assert_eq!(&digests[0], d, "timed runs diverged across thread counts");
    }
    if wall.len() >= 2 {
        let serial = wall[0].1;
        for &(threads, t) in &wall[1..] {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            println!(
                "speedup @ K={k}: {:.2}x with --threads {threads} vs --threads {} \
                 ({cores} cores available)",
                serial / t,
                wall[0].0
            );
        }
    }

    // ---- async-real coalescing case (ISSUE 5 acceptance) ------------
    // Per-arrival aggregation: per-event dispatch trains one learner at
    // a time no matter the pool width; the ε-window batches arrivals so
    // the train steps fan out. ε = 1 s of virtual time clusters the
    // free-running arrival stream into multi-learner windows.
    let ak = if run.smoke() { 50 } else { 200 };
    let eps = 1.0f64;
    let async_params = fleet_scale::RealFleetParams {
        ks: vec![ak],
        threads: vec![1, 8],
        ..params.clone()
    };
    let ads = fleet_scale::real_dataset(&async_params, ak);
    group(&format!(
        "async-real @ K={ak} ({} cycles): serial vs sharded vs coalesce ε={eps}s",
        async_params.cycles
    ));
    let mut async_wall: Vec<(&str, f64)> = Vec::new();
    for (mode, threads, epsilon) in [
        ("serial", 1usize, None),
        ("sharded8", 8usize, None),
        ("coalesce8", 8usize, Some(eps)),
    ] {
        let stats = run.bench(&format!("async_k{ak}/{mode}"), &cfg, || {
            fleet_scale::async_engine_run(&async_params, ak, threads, epsilon, &runtime, &ads)
                .expect("async engine run")
        });
        async_wall.push((mode, stats.mean_s));
    }
    // determinism: per-event dispatch is thread-invariant, and the
    // coalescing stream is itself bit-identical across thread counts
    let (r1, steps) =
        fleet_scale::async_engine_run(&async_params, ak, 1, None, &runtime, &ads).unwrap();
    let (r8, _) =
        fleet_scale::async_engine_run(&async_params, ak, 8, None, &runtime, &ads).unwrap();
    assert_eq!(
        record_digest(&r1),
        record_digest(&r8),
        "per-event async diverged across thread counts"
    );
    let (c1, _) =
        fleet_scale::async_engine_run(&async_params, ak, 1, Some(eps), &runtime, &ads).unwrap();
    let (c8, csteps) =
        fleet_scale::async_engine_run(&async_params, ak, 8, Some(eps), &runtime, &ads).unwrap();
    assert_eq!(
        record_digest(&c1),
        record_digest(&c8),
        "coalescing (ε={eps}) diverged across thread counts"
    );
    println!("determinism: async per-event + coalescing streams thread-invariant OK");
    // steps/sec ratio, not wall-time ratio: the ε>0 stream completes a
    // different arrival count than the per-event one, so each mode is
    // normalized by its own step count.
    let serial_rate = steps as f64 / async_wall[0].1;
    for &(mode, t) in &async_wall[1..] {
        let mode_steps = if mode == "coalesce8" { csteps } else { steps };
        let rate = mode_steps as f64 / t;
        println!(
            "async speedup @ K={ak}: {:.2}x steps/sec with {mode} vs serial \
             ({rate:.1} vs {serial_rate:.1} steps/s)",
            rate / serial_rate
        );
    }

    // ---- batched train_many flushes @ K=5000 ------------------------
    // The coalesced ε-window flush is where the batched backend earns
    // its keep: thousands of same-shape learner steps per flush. Timed
    // batched (the default) vs the scalar per-learner oracle
    // (`with_per_learner_train`) on an identical run.
    let bk = if run.smoke() { 200 } else { 5_000 };
    let batched_params = fleet_scale::RealFleetParams {
        ks: vec![bk],
        cycles: 1,
        samples_per_learner: 12,
        test_samples: 256,
        ..params.clone()
    };
    let bds = fleet_scale::real_dataset(&batched_params, bk);
    group(&format!(
        "async-real batched flushes @ K={bk} (1 cycle, ε={eps}s, 8 threads): \
         train_many vs per-learner"
    ));
    let batched_stats = run.bench(&format!("async_k{bk}_batched"), &cfg, || {
        fleet_scale::async_engine_run_mode(
            &batched_params, bk, 8, Some(eps), false, &runtime, &bds,
        )
        .expect("batched async run")
    });
    let scalar_stats = run.bench(&format!("async_k{bk}_per_learner"), &cfg, || {
        fleet_scale::async_engine_run_mode(&batched_params, bk, 8, Some(eps), true, &runtime, &bds)
            .expect("per-learner async run")
    });
    println!(
        "batched flush speedup @ K={bk}: {:.2}x (train_many {:.0}ms vs per-learner {:.0}ms)",
        scalar_stats.mean_s / batched_stats.mean_s,
        batched_stats.mean_s * 1e3,
        scalar_stats.mean_s * 1e3,
    );

    // ---- hierarchical sharded coordinator @ phantom K=100k ----------
    // The 500k-scale enabler: per-shard event queues + regional
    // aggregators must cost nothing extra and change nothing — any
    // shard count is bit-identical to the flat k=1 coordinator, so the
    // only thing left to measure is wall clock.
    let pk = 100_000usize;
    let pcycles = if run.smoke() { 2 } else { 8 };
    group(&format!(
        "phantom async sharded coordinator @ K={pk} ({pcycles} cycles): --shards 1 vs 8"
    ));
    for shards in [1usize, 8] {
        run.bench(&format!("async_k{pk}_shard{shards}"), &cfg, || {
            fleet_scale::phantom_async_run(pk, shards, pcycles).expect("phantom async run")
        });
    }
    // shard-count determinism gate (runs in bench-smoke): the record
    // stream and the engine counters must be bit-identical whatever the
    // shard count, at a CI-sized fleet.
    let dk = 5_000usize;
    let (flat_records, flat_stats) =
        fleet_scale::phantom_async_run(dk, 1, 3).expect("flat phantom run");
    let flat_digest = record_digest(&flat_records);
    for shards in [2usize, 8] {
        let (records, stats) =
            fleet_scale::phantom_async_run(dk, shards, 3).expect("sharded phantom run");
        assert_eq!(
            flat_digest,
            record_digest(&records),
            "--shards {shards} changed the record stream vs the flat coordinator"
        );
        assert_eq!(
            flat_stats, stats,
            "--shards {shards} changed the engine stats vs the flat coordinator"
        );
    }
    println!("determinism: sharded coordinator bit-identical across shard counts OK\n");

    run.finish().expect("bench json");
}
