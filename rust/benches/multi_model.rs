//! Bench + regeneration harness for the **multi-model** subsystem.
//!
//! `cargo bench --bench multi_model` does four things:
//! 1. prints the multi-tenancy sweep tables: M ∈ {1, 2, 4, 8} concurrent
//!    models over K ∈ {100, 1000} churny learners — homogeneous
//!    (staleness-greedy, fixed B) and heterogeneous (mixed small/large
//!    per-model tasks, adaptive B, cost-model routing), phantom
//!    numerics (skipped under `--smoke`);
//! 2. proves the ISSUE acceptance points: M = 8, K = 1000 runs with
//!    churn — homogeneous and heterogeneous — complete and are
//!    byte-reproducible (report digests equal across two runs);
//! 3. times one full M = 8, K = 1000 engine run (scheduler + buffered
//!    aggregation + per-model sub-fleet solve hot path);
//! 4. times its heterogeneous counterpart (per-model specs + adaptive
//!    buffering + predictive routing over one churny fleet).
//!
//! Passthrough flags: `--smoke` (fast CI config), `--json PATH`
//! (machine-readable results; see scripts/bench_check.sh).

use asyncmel::aggregation::AggregationRule;
use asyncmel::allocation::AllocatorKind;
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::{ChurnConfig, ScenarioConfig};
use asyncmel::coordinator::{EventEngine, ExecMode, TrainOptions};
use asyncmel::experiments::multi_model;
use asyncmel::multimodel::{
    report_digest, AdaptiveBufferConfig, ModelTaskSpec, MultiModelConfig, MultiModelOptions,
    MultiModelReport, SchedulerKind,
};

fn print_sweep() {
    let params = multi_model::MultiModelParams::default();
    let rows = multi_model::run(&params).expect("multi-model sweep");
    println!("\n========== MULTI-MODEL — M concurrent models, shared churny fleet ==========");
    println!("{}", multi_model::table(&rows).render());
    println!("=============================================================================\n");

    // the heterogeneous counterpart: mixed small/large per-model tasks,
    // adaptive buffering, predictive cost-model routing
    let params = multi_model::MultiModelParams {
        hetero: true,
        adaptive: Some(AdaptiveBufferConfig::with_b_max(8)),
        scheduler: SchedulerKind::CostModel,
        ..Default::default()
    };
    let rows = multi_model::run(&params).expect("hetero multi-model sweep");
    println!("===== MULTI-MODEL (hetero) — small/large mix, adaptive B, cost-model =====");
    println!("{}", multi_model::table(&rows).render());
    println!("===========================================================================\n");
}

fn run_k1000_m8() -> MultiModelReport {
    let scenario = ScenarioConfig::paper_default()
        .with_learners(1000)
        .with_churn(ChurnConfig::new(1.0, 120.0))
        .build();
    let mut engine = EventEngine::new(
        scenario,
        AllocatorKind::Eta,
        AggregationRule::FedAvg,
        ExecMode::Phantom,
    )
    .expect("engine");
    let opts = MultiModelOptions {
        train: TrainOptions { cycles: 8, ..Default::default() },
        multi: MultiModelConfig::new(8, 4, SchedulerKind::StalenessGreedy),
        ..Default::default()
    };
    engine.run_multi(&opts).expect("run_multi")
}

/// The heterogeneous acceptance point: mixed small/large models over
/// one churny K = 1000 fleet, adaptive buffering, predictive routing.
fn run_k1000_m8_hetero() -> MultiModelReport {
    let base = ScenarioConfig::paper_default();
    let specs = ModelTaskSpec::small_large_mix(8, base.total_samples, &base.task);
    let scenario = base
        .with_learners(1000)
        .with_churn(ChurnConfig::new(1.0, 120.0))
        .build();
    let mut engine = EventEngine::new(
        scenario,
        AllocatorKind::Eta,
        AggregationRule::FedAvg,
        ExecMode::Phantom,
    )
    .expect("engine");
    let opts = MultiModelOptions {
        train: TrainOptions { cycles: 8, ..Default::default() },
        multi: MultiModelConfig::new(8, 4, SchedulerKind::CostModel)
            .with_adaptive_buffer(AdaptiveBufferConfig::with_b_max(8))
            .with_specs(specs),
        ..Default::default()
    };
    engine.run_multi(&opts).expect("run_multi hetero")
}

fn main() {
    let mut run = BenchRun::from_env("multi_model");
    if !run.smoke() {
        print_sweep();
    }

    // ISSUE acceptance: M = 8, K = 1000 with churn, deterministically.
    let a = report_digest(&run_k1000_m8());
    let b = report_digest(&run_k1000_m8());
    assert_eq!(a, b, "M=8 K=1000 churny multi-model run must be byte-reproducible");
    println!("determinism: M=8, K=1000 with churn reproduces byte-for-byte OK\n");

    // …and the heterogeneous/adaptive/predictive path holds the same bar.
    let a = report_digest(&run_k1000_m8_hetero());
    let b = report_digest(&run_k1000_m8_hetero());
    assert_eq!(a, b, "heterogeneous multi-model run must be byte-reproducible");
    println!("determinism: hetero M=8, K=1000 (adaptive B, cost-model) reproduces OK\n");

    let cfg = BenchConfig {
        measure: std::time::Duration::from_secs(5),
        max_iters: 50,
        ..Default::default()
    };
    group("multi-model engine @ K=1000, M=8, B=4, churn (phantom numerics)");
    run.bench("multimodel/run_k1000_m8", &cfg, run_k1000_m8);

    group("hetero multi-model @ K=1000, M=8 small/large, adaptive B, cost-model");
    run.bench("multimodel/run_k1000_m8_hetero", &cfg, run_k1000_m8_hetero);

    run.finish().expect("bench json");
}
