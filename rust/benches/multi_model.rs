//! Bench + regeneration harness for the **multi-model** subsystem.
//!
//! `cargo bench --bench multi_model` does three things:
//! 1. prints the multi-tenancy sweep table: M ∈ {1, 2, 4, 8} concurrent
//!    models over K ∈ {100, 1000} churny learners, buffered async
//!    aggregation, staleness-greedy routing, phantom numerics (skipped
//!    under `--smoke`);
//! 2. proves the ISSUE acceptance point: an M = 8, K = 1000 run with
//!    churn completes and is byte-reproducible (report digests equal
//!    across two runs);
//! 3. times one full M = 8, K = 1000 engine run (scheduler + buffered
//!    aggregation + per-model sub-fleet solve hot path).
//!
//! Passthrough flags: `--smoke` (fast CI config), `--json PATH`
//! (machine-readable results; see scripts/bench_check.sh).

use asyncmel::aggregation::AggregationRule;
use asyncmel::allocation::AllocatorKind;
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::{ChurnConfig, ScenarioConfig};
use asyncmel::coordinator::{EventEngine, ExecMode, TrainOptions};
use asyncmel::experiments::multi_model;
use asyncmel::multimodel::{
    report_digest, MultiModelConfig, MultiModelOptions, MultiModelReport, SchedulerKind,
};

fn print_sweep() {
    let params = multi_model::MultiModelParams::default();
    let rows = multi_model::run(&params).expect("multi-model sweep");
    println!("\n========== MULTI-MODEL — M concurrent models, shared churny fleet ==========");
    println!("{}", multi_model::table(&rows).render());
    println!("=============================================================================\n");
}

fn run_k1000_m8() -> MultiModelReport {
    let scenario = ScenarioConfig::paper_default()
        .with_learners(1000)
        .with_churn(ChurnConfig::new(1.0, 120.0))
        .build();
    let mut engine = EventEngine::new(
        scenario,
        AllocatorKind::Eta,
        AggregationRule::FedAvg,
        ExecMode::Phantom,
    )
    .expect("engine");
    let opts = MultiModelOptions {
        train: TrainOptions { cycles: 8, ..Default::default() },
        multi: MultiModelConfig::new(8, 4, SchedulerKind::StalenessGreedy),
        ..Default::default()
    };
    engine.run_multi(&opts).expect("run_multi")
}

fn main() {
    let mut run = BenchRun::from_env("multi_model");
    if !run.smoke() {
        print_sweep();
    }

    // ISSUE acceptance: M = 8, K = 1000 with churn, deterministically.
    let a = report_digest(&run_k1000_m8());
    let b = report_digest(&run_k1000_m8());
    assert_eq!(a, b, "M=8 K=1000 churny multi-model run must be byte-reproducible");
    println!("determinism: M=8, K=1000 with churn reproduces byte-for-byte OK\n");

    group("multi-model engine @ K=1000, M=8, B=4, churn (phantom numerics)");
    let cfg = BenchConfig {
        measure: std::time::Duration::from_secs(5),
        max_iters: 50,
        ..Default::default()
    };
    run.bench("multimodel/run_k1000_m8", &cfg, run_k1000_m8);

    run.finish().expect("bench json");
}
