//! Bench for the **communication-fault chaos layer**: message loss,
//! duplication, corruption, and timeout/retry on the event engine.
//!
//! `cargo bench --bench chaos_fleet` does two things:
//! 1. verifies the comm contracts end-to-end (skipped under `--smoke`;
//!    also asserted in `rust/tests/comm_faults.rs`): a faults-off run
//!    is byte-identical to a run that never mentions the comm section,
//!    and a 5%-loss fleet is bit-identical across `--shards {1, 8}`;
//! 2. times a K = 5000 phantom async fleet under 5% loss (plus light
//!    duplication/corruption), flat and at 8 shards — every planned
//!    round draws from the comm stream, lost rounds ride the
//!    timeout/backoff ladder, and duplicates dedup at the aggregator.
//!
//! Passthrough flags: `--smoke` (fast CI config), `--json PATH`
//! (machine-readable results; see scripts/bench_check.sh).

use asyncmel::aggregation::{AggregationRule, AsyncAggregator};
use asyncmel::allocation::AllocatorKind;
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::{ChurnConfig, CommFaultConfig, ScenarioConfig};
use asyncmel::coordinator::{
    record_digest, EngineOptions, EnginePolicy, EventEngine, ExecMode, TrainOptions,
};

const K: usize = 5000;
const CYCLES: usize = 6;

fn lossy_cfg() -> CommFaultConfig {
    CommFaultConfig {
        downlink_loss_prob: 0.05,
        uplink_loss_prob: 0.05,
        duplicate_prob: 0.02,
        corrupt_prob: 0.01,
        ..CommFaultConfig::disabled()
    }
}

fn engine(comm: Option<CommFaultConfig>, shards: usize) -> EventEngine<'static> {
    let mut base = ScenarioConfig::paper_default()
        .with_learners(K)
        .with_churn(ChurnConfig::new(1.0, 120.0));
    if let Some(c) = comm {
        base = base.with_comm(c).unwrap();
    }
    EventEngine::new(
        base.build(),
        AllocatorKind::Eta,
        AggregationRule::FedAvg,
        ExecMode::Phantom,
    )
    .unwrap()
    .with_shards(shards)
}

fn opts() -> EngineOptions {
    EngineOptions {
        train: TrainOptions { cycles: CYCLES, ..Default::default() },
        policy: EnginePolicy::Async(AsyncAggregator::default()),
    }
}

fn verify_contracts() {
    println!("\n========== CHAOS FLEET — contract checks ==========");
    // a disabled comm section must be byte-identical to no section
    let bare = record_digest(&engine(None, 1).run(&opts()).unwrap());
    let off = record_digest(&engine(Some(CommFaultConfig::disabled()), 1).run(&opts()).unwrap());
    assert_eq!(bare, off, "a faults-off comm section perturbed the run");
    println!("faults-off oracle {} — byte-identical", &bare[..16]);

    // a lossy fleet must be bit-identical across shard counts
    let mut flat = engine(Some(lossy_cfg()), 1);
    let flat_digest = record_digest(&flat.run(&opts()).unwrap());
    let flat_stats = flat.stats;
    let mut sharded = engine(Some(lossy_cfg()), 8);
    let sharded_digest = record_digest(&sharded.run(&opts()).unwrap());
    assert_eq!(flat_digest, sharded_digest, "lossy fleet diverged at 8 shards");
    assert_eq!(flat_stats, sharded.stats, "lossy fleet stats diverged at 8 shards");
    assert!(flat_stats.timeouts > 0, "no timeouts at 5% loss — dead contract check");
    assert!(flat_stats.dupes_dropped > 0, "no dupes dropped — dead contract check");
    println!(
        "lossy fleet digest {} @ shards {{1, 8}} — bit-identical ({} timeouts, {} retries, {} dupes dropped)",
        &flat_digest[..16],
        flat_stats.timeouts,
        flat_stats.retries,
        flat_stats.dupes_dropped
    );
    println!("===================================================\n");
}

fn main() {
    let mut run = BenchRun::from_env("chaos_fleet");
    if !run.smoke() {
        verify_contracts();
    }

    group("chaos fleet @ K=5000, 6 cycles, 5% loss, async (phantom)");
    let cfg = BenchConfig {
        measure: std::time::Duration::from_secs(5),
        max_iters: 20,
        ..Default::default()
    };
    run.bench("async_k5000_loss", &cfg, || {
        let mut e = engine(Some(lossy_cfg()), 1);
        e.run(&opts()).unwrap()
    });
    run.bench("async_k5000_loss_shard8", &cfg, || {
        let mut e = engine(Some(lossy_cfg()), 8);
        e.run(&opts()).unwrap()
    });

    run.finish().expect("bench json");
}
