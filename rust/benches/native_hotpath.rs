//! Microbenchmarks for the native executor's forward/backward hot path
//! — the zero-alloc tiled rewrite's acceptance harness.
//!
//! `cargo bench --bench native_hotpath` times, through a persistent
//! [`Scratch`] (the zero-alloc steady state the engines run in):
//!
//! * one in-place `train_step_into` on the paper's 784→300→124→60→10
//!   stack at batch 128, and on the tiny 36→16→4 test stack at batch 32
//!   (the shapes the golden/e2e suites exercise);
//! * one `eval_batch_with` on the paper stack at batch 512;
//! * one batched `train_many_into` flush of 8 and 64 uniform learner
//!   tasks on the tiny stack vs the same tasks through the scalar
//!   per-learner `train_epochs_into` loop — the batched-GEMM
//!   acceptance comparison (speedup table printed at the end; batched
//!   must win at batch ≥ 8).
//!
//! Passthrough flags: `--smoke` (shrunk time budgets), `--json PATH`
//! (see scripts/bench_check.sh; keys are gated against
//! rust/benches/baseline.json).

use asyncmel::aggregation::ParamSet;
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::data::{synth, Batch, Dataset, SynthConfig};
use asyncmel::runtime::native::{BatchScratch, NativeExecutor, Scratch};
use asyncmel::runtime::{Executor, TrainTask};
use asyncmel::sim::Rng;

fn he_params(dims: &[usize], rng: &mut Rng) -> ParamSet {
    let mut out = Vec::new();
    for l in 0..dims.len() - 1 {
        let std = (2.0 / dims[l] as f64).sqrt();
        out.push(
            (0..dims[l] * dims[l + 1])
                .map(|_| rng.normal_ms(0.0, std) as f32)
                .collect(),
        );
        out.push(vec![0.0f32; dims[l + 1]]);
    }
    out
}

fn random_batch(rows: usize, f: usize, c: usize, rng: &mut Rng) -> Batch {
    let x: Vec<f32> = (0..rows * f).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; rows * c];
    for r in 0..rows {
        y[r * c + rng.below(c as u64) as usize] = 1.0;
    }
    Batch { x, y_onehot: y, mask: vec![1.0; rows], real: rows }
}

fn main() {
    let mut run = BenchRun::from_env("native_hotpath");
    let cfg = BenchConfig::default();
    let mut rng = Rng::new(0x40E7);

    group("native hot path — zero-alloc scratch, tiled kernels");

    // paper stack, train
    {
        let dims = vec![784usize, 300, 124, 60, 10];
        let exec = NativeExecutor::new(&dims);
        let mut params = he_params(&dims, &mut rng);
        let batch = random_batch(128, 784, 10, &mut rng);
        let mut scratch = Scratch::new();
        // low lr: repeated in-place steps stay numerically tame
        run.bench("train_step/paper_b128", &cfg, || {
            exec.train_step_into(&mut scratch, &mut params, &batch, 0.001)
        });
    }

    // tiny stack, train (the engine-test shape: step cost ~ µs, where
    // the old per-step allocations dominated)
    {
        let dims = vec![36usize, 16, 4];
        let exec = NativeExecutor::new(&dims);
        let mut params = he_params(&dims, &mut rng);
        let batch = random_batch(32, 36, 4, &mut rng);
        let mut scratch = Scratch::new();
        run.bench("train_step/tiny_b32", &cfg, || {
            exec.train_step_into(&mut scratch, &mut params, &batch, 0.001)
        });
    }

    // paper stack, eval
    {
        let dims = vec![784usize, 300, 124, 60, 10];
        let exec = NativeExecutor::new(&dims);
        let params = he_params(&dims, &mut rng);
        let batch = random_batch(512, 784, 10, &mut rng);
        let mut scratch = Scratch::new();
        run.bench("eval_batch/paper_b512", &cfg, || {
            exec.eval_batch_with(&mut scratch, &params, &batch)
        });
    }

    // batched train_many vs the scalar per-learner loop: a coalesced
    // flush of uniform (τ=1, d=48) tasks on the engine-test stack. Both
    // sides run through persistent scratches (their zero-alloc steady
    // states); per-outcome parameter clones are inherent to both APIs.
    let data: Dataset = synth::generate(&SynthConfig {
        side: 6,
        classes: 4,
        train: 4096,
        test: 32,
        noise_std: 0.4,
        ..SynthConfig::default()
    })
    .train;
    let dims = vec![36usize, 16, 4];
    let exec = NativeExecutor::new(&dims);
    let (d, tau, train_batch, lr) = (48usize, 1u64, 32usize, 0.001f32);
    let n = (data.x.len() / data.features) as u64;
    let mut speedups: Vec<(usize, f64, f64)> = Vec::new();
    group("batched train_many vs per-learner loop — tiny stack, τ=1, d=48");
    for nb in [8usize, 64] {
        let owned: Vec<(ParamSet, Vec<u32>)> = (0..nb)
            .map(|_| {
                let p = he_params(&dims, &mut rng);
                let shard: Vec<u32> = (0..d).map(|_| rng.below(n) as u32).collect();
                (p, shard)
            })
            .collect();
        let tasks: Vec<TrainTask<'_>> = owned
            .iter()
            .map(|(p, s)| TrainTask { params: p, shard: s, tau })
            .collect();
        let mut bs = BatchScratch::new();
        let batched = run.bench(&format!("train_many/b{nb}"), &cfg, || {
            exec.train_many_into(&mut bs, &tasks, &data, train_batch, lr)
                .expect("batched flush")
        });
        let mut scratch = Scratch::new();
        let scalar = run.bench(&format!("per_learner_loop/b{nb}"), &cfg, || {
            tasks
                .iter()
                .map(|t| {
                    let mut local = t.params.clone();
                    Executor::train_epochs_into(
                        &exec,
                        &mut scratch,
                        &mut local,
                        &data,
                        t.shard,
                        t.tau,
                        train_batch,
                        lr,
                    )
                    .map(|loss| (local, loss))
                    .expect("scalar task")
                })
                .collect::<Vec<_>>()
        });
        speedups.push((nb, scalar.mean_s, batched.mean_s));
    }
    println!("\nbatched train_many speedup (tiny 36→16→4 stack, τ=1, d=48):");
    println!("{:>6} {:>14} {:>14} {:>9}", "batch", "per-learner", "train_many", "speedup");
    for (nb, scalar_s, batched_s) in &speedups {
        println!(
            "{:>6} {:>12.1}µs {:>12.1}µs {:>8.2}x",
            nb,
            scalar_s * 1e6,
            batched_s * 1e6,
            scalar_s / batched_s
        );
    }
    println!();

    run.finish().expect("bench json");
}
