//! Bench + regeneration harness for the **fleet-scale** event engine.
//!
//! `cargo bench --bench fleet_scale` does two things:
//! 1. prints the fleet-scale sweep table: K ∈ {10, 100, 1000, 5000}
//!    learners with Poisson join / exponential-lifetime churn, phantom
//!    numerics — the ROADMAP scaling story (skipped under `--smoke`);
//! 2. times one full engine run at K = 1000 (event-queue + allocator
//!    hot path) and the per-event cost of the queue itself.
//!
//! Passthrough flags: `--smoke` (fast CI config), `--json PATH`
//! (machine-readable results; see scripts/bench_check.sh).

use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::{ChurnConfig, ScenarioConfig};
use asyncmel::coordinator::{EngineOptions, EventEngine, ExecMode, TrainOptions};
use asyncmel::experiments::fleet_scale;
use asyncmel::sim::EventQueue;

fn print_sweep() {
    let params = fleet_scale::FleetScaleParams::default();
    let rows = fleet_scale::run(&params).expect("fleet sweep");
    println!("\n========== FLEET SCALE — event engine with churn ==========");
    println!("{}", fleet_scale::table(&rows).render());
    println!("===========================================================\n");
}

fn main() {
    let mut run = BenchRun::from_env("fleet_scale");
    if !run.smoke() {
        print_sweep();
    }

    group("event engine @ K=1000, 8 cycles, churn (phantom numerics)");
    let cfg = BenchConfig {
        measure: std::time::Duration::from_secs(5),
        max_iters: 50,
        ..Default::default()
    };
    run.bench("engine/run_k1000", &cfg, || {
        let scenario = ScenarioConfig::paper_default()
            .with_learners(1000)
            .with_churn(ChurnConfig::new(1.0, 120.0))
            .build();
        let mut engine = EventEngine::new(
            scenario,
            asyncmel::allocation::AllocatorKind::Eta,
            asyncmel::aggregation::AggregationRule::FedAvg,
            ExecMode::Phantom,
        )
        .unwrap();
        let opts = EngineOptions {
            train: TrainOptions { cycles: 8, ..Default::default() },
            ..Default::default()
        };
        engine.run(&opts).unwrap()
    });

    group("event queue push+pop (10k events)");
    run.bench("queue/churn_10k", &BenchConfig::default(), || {
        let mut q = EventQueue::new();
        let mut acc = 0.0f64;
        for i in 0..10_000u64 {
            q.push((i % 97) as f64 * 0.5, i);
        }
        while let Some((t, _)) = q.pop() {
            acc += t;
        }
        acc
    });

    run.finish().expect("bench json");
}
