//! Bench for **trace-driven workload replay** on the event engine.
//!
//! `cargo bench --bench trace_replay` does two things:
//! 1. verifies the replay contract end-to-end: the same diurnal trace
//!    replayed twice, and across `--shards {1, 8}`, produces
//!    bit-identical records (skipped under `--smoke` — the contract is
//!    also asserted in `rust/tests/checkpoint_restore.rs`);
//! 2. times a K = 5000 phantom fleet replaying a diurnal capacity
//!    trace with background Poisson churn under the async policy, flat
//!    and at 8 coordinator shards.
//!
//! Passthrough flags: `--smoke` (fast CI config), `--json PATH`
//! (machine-readable results; see scripts/bench_check.sh).

use asyncmel::aggregation::{AggregationRule, AsyncAggregator};
use asyncmel::allocation::AllocatorKind;
use asyncmel::benchkit::{group, BenchConfig, BenchRun};
use asyncmel::config::{ChurnConfig, ScenarioConfig, TraceConfig};
use asyncmel::coordinator::{
    record_digest, EngineOptions, EnginePolicy, EventEngine, ExecMode, TrainOptions,
};

const K: usize = 5000;
const CYCLES: usize = 6;

fn trace() -> TraceConfig {
    // one diurnal period over the run's horizon (6 × 15 s), capacity
    // swinging between K/2 and 2K across 12 retarget points, 4 regions
    TraceConfig::gen_diurnal(11, 90.0, 90.0, 12, K / 2, 2 * K, 4)
}

fn engine(shards: usize) -> EventEngine<'static> {
    let scenario = ScenarioConfig::paper_default()
        .with_learners(K)
        .with_churn(ChurnConfig::new(1.0, 120.0))
        .with_trace(trace())
        .unwrap()
        .build();
    EventEngine::new(
        scenario,
        AllocatorKind::Eta,
        AggregationRule::FedAvg,
        ExecMode::Phantom,
    )
    .unwrap()
    .with_shards(shards)
}

fn opts() -> EngineOptions {
    EngineOptions {
        train: TrainOptions { cycles: CYCLES, ..Default::default() },
        policy: EnginePolicy::Async(AsyncAggregator::default()),
    }
}

fn verify_replay() {
    println!("\n========== TRACE REPLAY — bit-identity check ==========");
    let reference = record_digest(&engine(1).run(&opts()).unwrap());
    let again = record_digest(&engine(1).run(&opts()).unwrap());
    assert_eq!(reference, again, "same trace, same digest");
    let sharded = record_digest(&engine(8).run(&opts()).unwrap());
    assert_eq!(reference, sharded, "replay diverged at 8 shards");
    println!("replay digest {} @ shards {{1, 1, 8}} — bit-identical", &reference[..16]);
    println!("=======================================================\n");
}

fn main() {
    let mut run = BenchRun::from_env("trace_replay");
    if !run.smoke() {
        verify_replay();
    }

    group("diurnal trace replay @ K=5000, 6 cycles, async (phantom)");
    let cfg = BenchConfig {
        measure: std::time::Duration::from_secs(5),
        max_iters: 20,
        ..Default::default()
    };
    run.bench("async_k5000", &cfg, || {
        let mut e = engine(1);
        e.run(&opts()).unwrap()
    });
    run.bench("async_k5000_shard8", &cfg, || {
        let mut e = engine(8);
        e.run(&opts()).unwrap()
    });

    run.finish().expect("bench json");
}
